"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import PAD_ID
from repro.kernels.ops import node2vec_step_op, sgns_fused_op
from repro.kernels.ref import node2vec_step_ref, sgns_fused_ref


def _make_step_inputs(rng, w, d, dp):
    deg = rng.integers(1, d + 1, w)
    cand = np.full((w, d), PAD_ID, np.int32)
    cw = np.zeros((w, d), np.float32)
    for i in range(w):
        ids = np.sort(rng.choice(10000, size=deg[i], replace=False))
        cand[i, :deg[i]] = ids
        cw[i, :deg[i]] = rng.random(deg[i]).astype(np.float32) + 0.1
    degp = rng.integers(1, dp + 1, w)
    prev = np.full((w, dp), PAD_ID, np.int32)
    for i in range(w):
        pool = np.unique(np.concatenate(
            [cand[i, :deg[i]], rng.choice(10000, size=dp)]))
        ids = np.sort(rng.choice(pool, size=min(degp[i], len(pool)),
                                 replace=False).astype(np.int32))
        prev[i, :len(ids)] = ids
    u = cand[np.arange(w), rng.integers(0, deg)]
    r = rng.random(w).astype(np.float32)
    return cand, cw, u, prev, r


@pytest.mark.parametrize("w,d,dp", [(16, 8, 8), (64, 130, 40), (256, 128, 128),
                                    (7, 200, 300), (33, 64, 1)])
@pytest.mark.parametrize("pq", [(0.5, 2.0), (2.0, 0.5), (1.0, 1.0)])
def test_node2vec_step_kernel_matches_ref(w, d, dp, pq):
    rng = np.random.default_rng(w * d + dp)
    cand, cw, u, prev, r = _make_step_inputs(rng, w, d, dp)
    args = tuple(map(jnp.asarray, (cand, cw, u, prev, r)))
    got = np.asarray(node2vec_step_op(*args, *pq))
    want = np.asarray(node2vec_step_ref(*args, *pq))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("w,d,dp,seed", [
    (1, 1, 1, 0), (1, 40, 40, 1), (64, 1, 40, 2), (64, 40, 1, 3),
    (2, 3, 5, 4), (17, 29, 11, 5), (31, 40, 23, 6), (64, 17, 40, 7),
    (5, 13, 37, 8), (48, 25, 25, 100),
])
def test_node2vec_step_kernel_property(w, d, dp, seed):
    rng = np.random.default_rng(seed)
    cand, cw, u, prev, r = _make_step_inputs(rng, w, d, dp)
    args = tuple(map(jnp.asarray, (cand, cw, u, prev, r)))
    got = np.asarray(node2vec_step_op(*args, 0.5, 2.0))
    want = np.asarray(node2vec_step_ref(*args, 0.5, 2.0))
    assert np.array_equal(got, want)
    # sampled slots always index a live candidate
    deg = (cand != PAD_ID).sum(1)
    assert np.all(got < np.maximum(deg, 1))


@pytest.mark.parametrize("b,k,d", [(8, 1, 16), (64, 5, 32), (100, 8, 128),
                                   (512, 5, 200), (3, 12, 300)])
def test_sgns_kernel_matches_autodiff(b, k, d):
    rng = np.random.default_rng(b + k + d)
    ci = rng.normal(size=(b, d)).astype(np.float32)
    po = rng.normal(size=(b, d)).astype(np.float32)
    no = rng.normal(size=(b, k, d)).astype(np.float32)
    valid = (rng.random(b) > 0.2).astype(np.float32)
    got = sgns_fused_op(*map(jnp.asarray, (ci, po, no, valid)))
    want = sgns_fused_ref(*map(jnp.asarray, (ci, po, no, valid)))
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                   atol=3e-4, rtol=3e-4)


def test_sgns_kernel_masked_rows_zero_grad():
    rng = np.random.default_rng(0)
    b, k, d = 16, 4, 32
    ci = rng.normal(size=(b, d)).astype(np.float32)
    po = rng.normal(size=(b, d)).astype(np.float32)
    no = rng.normal(size=(b, k, d)).astype(np.float32)
    valid = np.zeros(b, np.float32)
    valid[:4] = 1.0
    loss, g_ci, g_po, g_no = sgns_fused_op(
        *map(jnp.asarray, (ci, po, no, valid)))
    assert np.all(np.asarray(g_ci)[4:] == 0)
    assert np.all(np.asarray(g_no)[4:] == 0)
