"""Streaming ingestion + dataset registry (repro.data.ingest).

Covers the PR's acceptance criteria:
* chunked two-pass builder == CSRGraph.from_edges, bit-for-bit;
* builder peak transient allocation bounded by O(n + chunk), asserted with
  tracemalloc against a budget provably smaller than any O(m) temporary;
* disk cache loads are np.memmap-backed and roundtrip exactly;
* edges -> CSR -> disk cache -> load_graph reload -> **bit-identical
  walks** from one WalkPlan + seed on all three backends, including the
  degree-relabeled layout;
* ShardedGraph.from_csr (shard-by-shard pack) == the dense
  PaddedGraph -> ShardedGraph.build path, field by field.
"""
import os
import tracemalloc

import numpy as np
import pytest

from repro.core import rmat
from repro.core.graph import CSRGraph, PaddedGraph
from repro.core.walk_distributed import ShardedGraph
from repro.data import ingest
from repro.data.ingest import (_load_dataset as load_dataset, csr_from_chunks,
                               edgelist_to_csr, load_csr, parse_spec,
                               relabel_by_degree, save_csr, write_edgelist)
from repro.engine import WalkEngine, WalkPlan


def load_graph(spec, cache_dir=None):
    # the non-deprecated spelling of the old load_graph helper
    return load_dataset(spec, cache_dir=cache_dir).graph


def _pair_weights(src, dst):
    """Deterministic weight per undirected pair, so dedup order can't
    change which weight survives."""
    lo, hi = np.minimum(src, dst), np.maximum(src, dst)
    return ((lo * 31 + hi) % 97 + 1).astype(np.float32)


def _random_edges(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return src, dst, _pair_weights(src, dst)


def _chunks_of(src, dst, wgt, chunk):
    def chunks():
        for i in range(0, len(src), chunk):
            yield (src[i:i + chunk].astype(np.int64),
                   dst[i:i + chunk].astype(np.int64), wgt[i:i + chunk])
    return chunks


def _csr_equal(a: CSRGraph, b: CSRGraph) -> bool:
    return (a.n == b.n
            and np.array_equal(np.asarray(a.row_ptr), np.asarray(b.row_ptr))
            and np.array_equal(np.asarray(a.col), np.asarray(b.col))
            and np.array_equal(np.asarray(a.wgt), np.asarray(b.wgt)))


# ------------------------------------------------------------------ builder

@pytest.mark.parametrize("n,m,chunk,seed", [
    (2, 1, 4, 0), (16, 40, 7, 1), (100, 1000, 64, 2), (300, 4000, 513, 3),
    (50, 5000, 4096, 4),   # chunk > m: single-chunk path
])
def test_chunk_builder_matches_from_edges(n, m, chunk, seed):
    src, dst, wgt = _random_edges(n, m, seed)
    ref = CSRGraph.from_edges(n, src, dst, wgt)
    g = csr_from_chunks(_chunks_of(src, dst, wgt, chunk), n=n,
                        block_edges=chunk)
    assert _csr_equal(g, ref)


def test_chunk_builder_discovers_n():
    src = np.array([0, 5, 2]); dst = np.array([5, 2, 7])
    g = csr_from_chunks(_chunks_of(src, dst, np.ones(3, np.float32), 2))
    assert g.n == 8
    assert g.m == 6   # symmetrized


def test_chunk_builder_directed_no_dedup():
    src = np.array([0, 0, 1]); dst = np.array([1, 1, 2])
    w = np.array([2.0, 3.0, 4.0], np.float32)
    g = csr_from_chunks(_chunks_of(src, dst, w, 2), n=3, undirected=False,
                        dedup=False)
    assert g.m == 3 and list(g.neighbors(0)) == [1, 1]
    gd = csr_from_chunks(_chunks_of(src, dst, w, 2), n=3, undirected=False,
                         dedup=True)
    assert gd.m == 2
    assert gd.weights(0)[0] == 2.0   # first-arriving weight wins


def test_chunk_builder_rejects_out_of_range_ids():
    src = np.array([0, 9]); dst = np.array([1, 2])
    with pytest.raises(ValueError, match=">= n"):
        csr_from_chunks(_chunks_of(src, dst, np.ones(2, np.float32), 8), n=4)


def test_chunk_builder_peak_memory_bounded():
    """Acceptance criterion: peak transient allocation is bounded by the
    chunk size (plus the CSR output + O(n) counters) — asserted against a
    budget provably below the cheapest possible O(m) temporary, so any
    whole-edge-list materialization fails this test."""
    n, m, chunk = 50_000, 1_000_000, 16_384
    rng = np.random.default_rng(7)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    wgt = np.ones(m, np.float32)
    chunks = _chunks_of(src, dst, wgt, chunk)

    tracemalloc.start()
    try:
        tracemalloc.reset_peak()
        g = csr_from_chunks(chunks, n=n, block_edges=chunk)
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()

    m_placed = 2 * int((src != dst).sum())        # symmetrized placements
    out_bytes = (n + 1) * 8 + m_placed * (4 + 4)  # indptr + col + wgt
    overhead_budget = 24 * 8 * chunk + 32 * n + (1 << 20)
    # the budget must itself rule out even a single O(m) int32 temporary
    assert overhead_budget < m_placed * 4
    assert peak - out_bytes < overhead_budget, (
        f"peak {peak / 2**20:.1f} MiB exceeds CSR output "
        f"{out_bytes / 2**20:.1f} MiB + O(n + chunk) budget "
        f"{overhead_budget / 2**20:.1f} MiB")
    assert g.m <= m_placed


# ------------------------------------------------------- text parsing + IO

def test_edgelist_text_parsing(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("# comment\n% other comment\n"
                 "0 1 2.5\n1,2,3.5\n\n2 0\n")
    g = edgelist_to_csr(str(p), n=3)
    assert g.m == 6
    assert g.weights(0)[0] == 2.5          # 0-1 weighted
    assert g.weights(0)[1] == 1.0          # 2-0 default weight
    assert np.array_equal(g.neighbors(1), [0, 2])


def test_edgelist_roundtrip_matches_from_edges(tmp_path):
    src, dst, wgt = _random_edges(200, 3000, 11)
    ref = CSRGraph.from_edges(200, src, dst, wgt)
    path = tmp_path / "edges.txt"
    write_edgelist(str(path), src, dst, wgt)
    g = edgelist_to_csr(str(path), n=200, chunk_edges=997)
    assert _csr_equal(g, ref)


def test_csr_cache_roundtrip_is_memmap(tmp_path, small_graph):
    d = save_csr(small_graph, str(tmp_path / "cache"))
    g = load_csr(d)
    assert isinstance(g.col, np.memmap)
    assert isinstance(g.row_ptr, np.memmap)
    assert _csr_equal(g, small_graph)
    g2 = load_csr(d, mmap=False)
    assert not isinstance(g2.col, np.memmap)
    assert _csr_equal(g2, small_graph)


def test_csr_cache_version_check(tmp_path, small_graph):
    d = save_csr(small_graph, str(tmp_path / "c"))
    meta = os.path.join(d, "meta.json")
    with open(meta) as f:
        text = f.read()
    with open(meta, "w") as f:
        f.write(text.replace(f'"version": {ingest.CSR_FORMAT_VERSION}',
                             '"version": 0'))
    with pytest.raises(ValueError, match="version"):
        load_csr(d)


def test_load_graph_edgelist_cache_hits(tmp_path):
    src, dst, wgt = _random_edges(64, 400, 5)
    path = tmp_path / "e.txt"
    write_edgelist(str(path), src, dst, wgt)
    cache = str(tmp_path / "cache")
    g1 = load_graph(f"edgelist:{path},n=64", cache_dir=cache)
    assert isinstance(g1.col, np.memmap)      # built then memmap-reloaded
    subdirs = os.listdir(cache)
    assert len(subdirs) == 1
    g2 = load_graph(f"edgelist:{path},n=64", cache_dir=cache)  # cache hit
    assert os.listdir(cache) == subdirs
    assert _csr_equal(g1, g2)
    assert _csr_equal(g1, CSRGraph.from_edges(64, src, dst, wgt))


# ---------------------------------------------------------------- registry

def test_parse_spec_grammar():
    assert parse_spec("wec:k=8,deg=12") == ("wec", None, {"k": "8",
                                                          "deg": "12"})
    assert parse_spec("edgelist:/a/b.txt,n=10") == (
        "edgelist", "/a/b.txt", {"n": "10"})
    with pytest.raises(ValueError, match="two positional"):
        parse_spec("edgelist:/a,/b")
    with pytest.raises(ValueError, match="family"):
        parse_spec(":k=1")


@pytest.mark.parametrize("spec,builder", [
    ("er:k=6,deg=6,seed=2", lambda: rmat.er(6, avg_degree=6, seed=2)),
    ("wec:k=7,deg=10,seed=1", lambda: rmat.wec(7, avg_degree=10, seed=1)),
    ("skew:s=3,k=7,deg=12,seed=0",
     lambda: rmat.skew(3, k=7, avg_degree=12, seed=0)),
    ("rmat:k=6,deg=8,a=0.45,b=0.22,c=0.22,d=0.11,seed=4",
     lambda: rmat.rmat_graph(6, 8, 0.45, 0.22, 0.22, 0.11, seed=4)),
])
def test_registry_matches_direct_builders(spec, builder):
    assert _csr_equal(load_graph(spec), builder())


def test_registry_rejects_unknown_options():
    with pytest.raises(ValueError, match="unknown option"):
        load_graph("wec:k=6,degree=16")        # typo for deg=
    with pytest.raises(ValueError, match="unknown option"):
        load_graph("edgelist:/tmp/x.txt,cap=4")


def test_relabeled_edgelist_cache_stores_final_layout(tmp_path):
    src, dst, wgt = _random_edges(64, 500, 21)
    path = tmp_path / "e.txt"
    write_edgelist(str(path), src, dst, wgt)
    spec = f"edgelist:{path},n=64,relabel=degree"
    mem = load_dataset(spec)
    cache = str(tmp_path / "cache")
    disk = load_dataset(spec, cache_dir=cache)
    assert _csr_equal(mem.graph, disk.graph)
    assert np.array_equal(mem.perm, np.asarray(disk.perm))   # perm cached
    # relabeled and plain specs cache to distinct entries
    load_graph(f"edgelist:{path},n=64", cache_dir=cache)
    assert len(os.listdir(cache)) == 2


def test_registry_sbm_labels_and_errors():
    ds = load_dataset("sbm:n=120,c=3,pin=0.1,pout=0.01,seed=0")
    assert ds.labels is not None and ds.labels.shape == (120,)
    assert ds.graph.n == 120
    with pytest.raises(ValueError, match="unknown graph family"):
        load_graph("livejournal:k=1")
    with pytest.raises(ValueError, match="required"):
        load_graph("wec:deg=10")
    with pytest.raises(ValueError, match="relabel"):
        load_graph("wec:k=6,relabel=random")


# ----------------------------------------------------------------- relabel

def test_relabel_by_degree_invariants(skewed_graph):
    g = skewed_graph
    r, perm = relabel_by_degree(g)
    assert sorted(perm.tolist()) == list(range(g.n))
    deg = r.deg
    assert np.all(deg[:-1] >= deg[1:])            # descending
    assert deg[0] == g.max_degree
    # edges + weights preserved under the permutation
    for v in [0, 1, g.n // 3, g.n - 1]:
        nb, w = g.neighbors(v), g.weights(v)
        order = np.argsort(perm[nb.astype(np.int64)])
        assert np.array_equal(perm[nb.astype(np.int64)][order],
                              r.neighbors(int(perm[v])))
        assert np.array_equal(w[order], r.weights(int(perm[v])))


def test_relabel_hot_set_is_prefix(skewed_graph):
    cap = 24
    r, _ = relabel_by_degree(skewed_graph)
    hot = np.nonzero(r.deg > cap)[0]
    assert np.array_equal(hot, np.arange(len(hot)))   # contiguous prefix


def test_load_dataset_relabel_permutes_labels():
    plain = load_dataset("sbm:n=120,c=3,pin=0.1,pout=0.01,seed=0")
    rel = load_dataset("sbm:n=120,c=3,pin=0.1,pout=0.01,seed=0,"
                       "relabel=degree")
    assert rel.perm is not None
    # label of old vertex v must follow v to its new id
    assert np.array_equal(rel.labels[rel.perm], plain.labels)


# ------------------------------------------------- sharded direct build

@pytest.mark.parametrize("cap,num_shards", [
    (None, 1), (None, 3), (24, 2), (24, 4), (8, 2),
])
def test_sharded_from_csr_matches_dense_path(skewed_graph, cap, num_shards):
    """Shard-by-shard CSR pack == dense PaddedGraph -> ShardedGraph.build,
    every field bit-identical (including the no-hot sentinel when
    cap=None)."""
    old = ShardedGraph.build(PaddedGraph.build(skewed_graph, cap=cap),
                             num_shards)
    new = ShardedGraph.from_csr(skewed_graph, num_shards, cap=cap)
    assert (old.n, old.n_orig, old.cap, old.hot_cap, old.num_shards) == \
           (new.n, new.n_orig, new.cap, new.hot_cap, new.num_shards)
    for f in ("adj", "wgt", "alias_p", "alias_i", "deg", "hot_ids",
              "hot_adj", "hot_wgt", "hot_alias_p", "hot_alias_i",
              "hot_deg", "hot_wmin", "hot_wmax"):
        a = np.asarray(getattr(old, f))
        b = np.asarray(getattr(new, f))
        assert a.shape == b.shape and np.array_equal(a, b), f


# --------------------------------------- end-to-end roundtrip (acceptance)

@pytest.mark.parametrize("relabel", [False, True])
def test_roundtrip_walks_bit_identical_all_backends(tmp_path, relabel):
    """edges -> chunked CSR -> disk cache -> memmap reload -> WalkEngine:
    the in-memory and disk-cache graphs give bit-identical walks from one
    WalkPlan + seed on all three backends (sharded runs on the in-process
    single-device mesh), with and without the degree-relabeled layout."""
    src, dst, wgt = _random_edges(128, 1500, 13)
    path = tmp_path / "edges.txt"
    write_edgelist(str(path), src, dst, wgt)
    suffix = ",relabel=degree" if relabel else ""
    spec = f"edgelist:{path},n=128{suffix}"

    g_mem = load_graph(spec)                                  # in-memory
    cache = str(tmp_path / "cache")
    load_graph(spec, cache_dir=cache)                         # build cache
    g_disk = load_graph(spec, cache_dir=cache)                # memmap hit
    # the cache stores the *final* layout, so even the relabeled graph
    # memmaps straight from disk (no per-load relabel pass)
    assert isinstance(g_disk.col, np.memmap)
    assert _csr_equal(g_mem, g_disk)

    plan_kw = dict(p=0.5, q=2.0, length=8, cap=16)
    walks = {}
    for backend in ("reference", "sharded", "fused"):
        plan = WalkPlan(backend=backend, **plan_kw)
        w_mem = WalkEngine.build(g_mem, plan).run(seed=9)
        w_disk = WalkEngine.build(g_disk, plan).run(seed=9)
        assert np.array_equal(w_mem.walks, w_disk.walks), backend
        assert w_disk.stats.dropped == 0
        walks[backend] = w_mem.walks
    assert np.array_equal(walks["reference"], walks["sharded"])
    assert np.array_equal(walks["reference"], walks["fused"])


def test_engine_builds_from_spec_string(small_graph):
    plan = WalkPlan(p=0.5, q=2.0, length=5, cap=16)
    via_spec = WalkEngine.build("wec:k=8,deg=12,seed=1", plan).run(seed=3)
    direct = WalkEngine.build(small_graph, plan).run(seed=3)
    assert np.array_equal(via_spec.walks, direct.walks)
