"""Unified WalkEngine API: backend parity, stats, rounds, validation.

The tri-backend parity tests are the PR's core guarantee: one WalkPlan +
seed -> bit-identical walks on `reference`, `sharded` (fake devices, run in
a subprocess because jax locks the device count at first init), and `fused`
(Pallas kernel, interpret mode). This exercises the `walker_key` RNG
contract: keys are fold_in(fold_in(seed, walker), step) — a pure function of
(walker, step), never of device layout or backend.
"""
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import rmat
from repro.core.graph import PaddedGraph
from repro.engine import WalkEngine, WalkPlan, WalkStats, round_seed


@pytest.mark.parametrize("mode", ["exact", "approx", "approx_always"])
def test_reference_fused_parity(skewed_graph, mode):
    """The fused (Pallas) backend implements the Sampler's exact draw
    verbatim — walks must be bit-identical to the reference backend."""
    kw = dict(p=0.5, q=2.0, length=8, mode=mode, approx_eps=5e-2, cap=24)
    ref = WalkEngine.build(skewed_graph, WalkPlan(backend="reference", **kw))
    fus = WalkEngine.build(skewed_graph, WalkPlan(backend="fused", **kw))
    r = ref.run(seed=11)
    f = fus.run(seed=11)
    assert np.array_equal(r.walks, f.walks)
    assert r.stats.backend == "reference" and f.stats.backend == "fused"
    assert f.stats.supersteps == 8 and f.stats.dropped == 0


PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.core import rmat
    from repro.engine import WalkEngine, WalkPlan

    g = rmat.skew(4, k=8, avg_degree=16, seed=3)
    walks = {{}}
    for backend in ("reference", "sharded", "fused"):
        plan = WalkPlan(p=0.5, q=2.0, length=10, mode="{mode}",
                        approx_eps=5e-2, cap=24, backend=backend)
        res = WalkEngine.build(g, plan).run(seed=5)
        assert res.stats.dropped == 0, res.stats
        walks[backend] = res.walks
    assert np.array_equal(walks["reference"], walks["sharded"]), "sharded"
    assert np.array_equal(walks["reference"], walks["fused"]), "fused"
    print("OK", walks["reference"].shape)
""")


def _run_subprocess(code):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["exact", "approx", "approx_always"])
def test_three_backend_parity(mode):
    """reference == sharded (2 fake devices) == fused, bit-identical, from
    one WalkPlan + seed."""
    _run_subprocess(PARITY_SCRIPT.format(mode=mode))


DROPS_SCRIPT = textwrap.dedent("""
    import os, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.core import rmat
    from repro.engine import WalkEngine, WalkPlan

    g = rmat.skew(4, k=8, avg_degree=16, seed=3)
    plan = WalkPlan(p=0.5, q=2.0, length=8, cap=24, backend="sharded",
                    capacity=1)             # starve the request exchange
    eng = WalkEngine.build(g, plan, mesh=None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = eng.run(seed=0)
    assert res.stats.dropped > 0, res.stats
    assert any("dropped" in str(w.message) for w in caught), caught
    strict = WalkEngine.build(g, WalkPlan(p=0.5, q=2.0, length=8, cap=24,
                                          backend="sharded", capacity=1,
                                          strict_drops=True))
    try:
        strict.run(seed=0)
        raise SystemExit("strict_drops did not raise")
    except RuntimeError as e:
        assert "dropped" in str(e)
    print("OK", res.stats.dropped)
""")


@pytest.mark.slow
def test_stats_surface_drops_and_strict_flag():
    """Starved exchange capacity -> WalkStats.dropped > 0 + warning;
    strict_drops upgrades the warning to an error."""
    _run_subprocess(DROPS_SCRIPT)


@pytest.mark.parametrize("wk,length", [(32, 8), (7, 5), (5, 2)])
def test_fused_persistent_pipeline_parity(small_graph, wk, length):
    """WalkPlan.pipeline on the fused backend routes exact FN-Base walks to
    the multi-superstep Pallas kernel (prev rows carried in VMEM) — walks
    must stay bit-identical to the reference backend, including odd walker
    counts and the minimal length-2 walk."""
    kw = dict(p=0.5, q=2.0, length=length)       # cap=None -> FN-Base
    ref = WalkEngine.build(small_graph, WalkPlan(backend="reference", **kw))
    fus = WalkEngine.build(small_graph,
                           WalkPlan(backend="fused", pipeline=True, **kw))
    assert fus._fused_persistent()               # the kernel path is live
    starts = ((np.arange(wk) * 3) % small_graph.n).astype(np.int32)
    wid = np.arange(wk, dtype=np.int32)
    r = ref.run(starts=starts, seed=11, walker_ids=wid)
    f = fus.run(starts=starts, seed=11, walker_ids=wid)
    assert np.array_equal(r.walks, f.walks)


@pytest.mark.parametrize("mode", ["approx", "approx_always"])
def test_fused_pipeline_fallback_parity(skewed_graph, mode):
    """Outside the persistent kernel's scope (hot-cache layout / approx
    sampling) the pipeline flag falls back to the per-step kernel — still
    bit-identical to the reference."""
    kw = dict(p=0.5, q=2.0, length=6, mode=mode, approx_eps=5e-2, cap=24)
    ref = WalkEngine.build(skewed_graph, WalkPlan(backend="reference", **kw))
    fus = WalkEngine.build(skewed_graph,
                           WalkPlan(backend="fused", pipeline=True, **kw))
    assert not fus._fused_persistent()
    assert np.array_equal(ref.run(seed=3).walks, fus.run(seed=3).walks)


def test_pipeline_flag_noop_on_reference(small_graph):
    """pipeline=True is a no-op for the reference backend: identical walks
    and zero overlap accounting (nothing is on the wire)."""
    kw = dict(p=0.5, q=2.0, length=6, cap=16)
    a = WalkEngine.build(small_graph, WalkPlan(**kw)).run(seed=4)
    b = WalkEngine.build(small_graph,
                         WalkPlan(pipeline=True, **kw)).run(seed=4)
    assert np.array_equal(a.walks, b.walks)
    assert b.stats.exposed_collective_bytes == 0
    assert b.stats.overlap_efficiency == 0.0


PIPELINE_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.core import rmat
    from repro.engine import WalkEngine, WalkPlan

    g = rmat.skew(4, k=8, avg_degree=16, seed=3)
    half = g.n // 2
    # odd per-shard counts give shard-misaligned cohort splits
    # (5 walkers/shard -> cohorts of 3 and 2); length 2 exercises the
    # peeled-epilogue-only pipeline
    for per_shard, length in ((8, 10), (5, 7), (3, 2)):
        a = (np.arange(per_shard, dtype=np.int32) * 7) % half
        starts = np.concatenate([a, a + half])
        wid = np.arange(starts.shape[0], dtype=np.int32)
        kw = dict(p=0.5, q=2.0, length=length, mode="{mode}",
                  approx_eps=5e-2, cap=24, strict_drops=True)
        runs = {{}}
        for name, plan in (
                ("reference", WalkPlan(backend="reference", **kw)),
                ("barrier", WalkPlan(backend="sharded", **kw)),
                ("pipelined", WalkPlan(backend="sharded", pipeline=True,
                                       **kw))):
            runs[name] = WalkEngine.build(g, plan).run(
                starts=starts, seed=5, walker_ids=wid)
        for name in ("barrier", "pipelined"):
            assert np.array_equal(runs["reference"].walks,
                                  runs[name].walks), (per_shard, length,
                                                      name)
            assert runs[name].stats.dropped == 0
        pip, bar = runs["pipelined"].stats, runs["barrier"].stats
        if length >= 2:
            assert pip.exposed_collective_bytes < pip.collective_bytes, pip
            assert pip.overlap_efficiency > 0, pip
            assert pip.exposed_collective_bytes < \\
                bar.exposed_collective_bytes, (pip, bar)
        assert bar.exposed_collective_bytes == bar.collective_bytes
    print("OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["exact", "approx"])
def test_pipelined_vs_barrier_parity(mode):
    """Tentpole lockdown: double-buffered cohort pipeline == barrier ==
    reference, bit-identical, under strict_drops — including odd per-shard
    walker counts (shard-misaligned cohort splits) and length 2."""
    _run_subprocess(PIPELINE_PARITY_SCRIPT.format(mode=mode))


PIPELINE_DROPS_SCRIPT = textwrap.dedent("""
    import os, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.core import rmat
    from repro.engine import WalkEngine, WalkPlan

    g = rmat.skew(4, k=8, avg_degree=16, seed=3)
    kw = dict(p=0.5, q=2.0, length=8, cap=24, capacity=1)  # starved
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        bar = WalkEngine.build(g, WalkPlan(backend="sharded", **kw)).run(
            seed=0)
        pip = WalkEngine.build(g, WalkPlan(backend="sharded", pipeline=True,
                                           **kw)).run(seed=0)
    # capacity is per-destination *per exchange*; a cohort's request rank is
    # <= its joint barrier rank, so pipelined drops form a subset of barrier
    # drops at equal capacity
    assert 0 < pip.stats.dropped <= bar.stats.dropped, (pip.stats,
                                                        bar.stats)
    assert pip.stats.exposed_collective_bytes < pip.stats.collective_bytes
    print("OK", bar.stats.dropped, pip.stats.dropped)
""")


@pytest.mark.slow
def test_pipelined_drops_bounded_by_barrier():
    """Starved exchange: the pipeline never drops more than the barrier
    loop at equal per-exchange capacity."""
    _run_subprocess(PIPELINE_DROPS_SCRIPT)


def test_rounds_stream_matches_individual_runs(small_graph):
    plan = WalkPlan(p=0.5, q=2.0, length=6, cap=16)
    eng = WalkEngine.build(small_graph, plan)
    streamed = [r.walks for r in eng.rounds(3, seed=9)]
    assert len(streamed) == 3
    for k, w in enumerate(streamed):
        direct = eng.run(seed=round_seed(9, k))
        assert np.array_equal(w, direct.walks), k
    # rounds differ from each other (seeds actually fold in the round)
    assert not np.array_equal(streamed[0], streamed[1])


def test_engine_stats_structure(small_graph):
    res = WalkEngine.build(small_graph, WalkPlan(length=4)).run(seed=0)
    assert isinstance(res.stats, WalkStats)
    assert res.stats.walkers == small_graph.n
    assert res.stats.collective_bytes == 0   # single-device: nothing on wire
    assert res.walks.shape == (small_graph.n, 4)


def test_build_accepts_prebuilt_padded_graph(small_graph):
    """A prebuilt PaddedGraph binds directly (no store, no repack) and
    walks identically to building from the CSR at the same plan."""
    pg = PaddedGraph.build(small_graph, cap=16)
    plan = WalkPlan(p=0.5, q=2.0, length=6, cap=16)
    direct = WalkEngine.build(pg, plan)
    assert direct.store is None
    via_csr = WalkEngine.build(small_graph, plan)
    assert via_csr.store is not None
    assert np.array_equal(direct.run(seed=3).walks,
                          via_csr.run(seed=3).walks)


def test_custom_starts_and_walker_ids(small_graph):
    """walker_ids default to start vertex ids; distinct explicit ids give
    distinct walks from the same start (the RNG folds in the walker id)."""
    eng = WalkEngine.build(small_graph, WalkPlan(length=5, cap=16))
    v = int(np.argmax(small_graph.deg))
    starts = np.full(8, v, np.int32)
    same = eng.run(starts=starts, seed=0)
    assert (same.walks == same.walks[0]).all()   # one walker id -> one walk
    distinct = eng.run(starts=starts, seed=0,
                       walker_ids=np.arange(8, dtype=np.int32))
    assert len({tuple(row) for row in distinct.walks}) > 1


def test_plan_validation():
    with pytest.raises(ValueError, match="backend"):
        WalkPlan(backend="gpu")
    with pytest.raises(ValueError, match="length"):
        WalkPlan(length=0)
    g = rmat.wec(6, avg_degree=8, seed=0)
    sharded_engine = WalkEngine.build(g, WalkPlan(length=4,
                                                  backend="sharded"))
    with pytest.raises(ValueError, match="analyze"):
        WalkEngine.build(g, WalkPlan(length=4)).analyze()
    del sharded_engine


def test_capacity_auto_validation():
    WalkPlan(capacity="auto")            # accepted
    WalkPlan(capacity=16)
    with pytest.raises(ValueError, match="capacity"):
        WalkPlan(capacity="turbo")
    with pytest.raises(ValueError, match="capacity"):
        WalkPlan(capacity=0)


def test_capacity_auto_headroom_skew5():
    """capacity='auto' on a Skew-5 graph: at least 2x headroom below the
    zero-drop worst case (capacity == walkers per shard), yet still above
    the max per-destination demand any exchange actually generates —
    checked by replaying reference walks through the NEIG slot accounting
    (a walker at a cold vertex owned by another shard consumes one request
    slot for that destination in its source shard's buffer)."""
    from repro.roofline.traffic import walk_auto_capacity

    g = rmat.skew(5, k=10, avg_degree=30, seed=0)
    cap, S, length = 32, 8, 12
    assert g.n % S == 0
    n_local = g.n // S
    deg = g.deg
    auto = walk_auto_capacity(deg, cap=cap, num_shards=S,
                              walkers_per_shard=n_local)
    worst = n_local                       # one slot per walker per dest
    assert auto * 2 <= worst, (auto, worst)

    plan = WalkPlan(backend="reference", cap=cap, length=length)
    walks = WalkEngine.build(g, plan).run(seed=0).walks
    is_hot = deg > cap                    # hot rows are replicated: no slot
    src = np.arange(g.n) // n_local       # walkers co-located with starts
    demand = 0
    for s in range(length - 1):           # superstep 0 reads the local row
        v = walks[:, s]
        need = (~is_hot[v]) & ((v // n_local) != src)
        counts = np.zeros((S, S), np.int64)
        np.add.at(counts, (src[need], v[need] // n_local), 1)
        demand = max(demand, int(counts.max()))
    assert 0 < demand <= auto, (demand, auto)


def test_capacity_auto_sharded_zero_drops():
    """End-to-end: a sharded engine built with capacity='auto' resolves to
    a concrete per-destination slot count and drops nothing."""
    g = rmat.skew(4, k=8, avg_degree=16, seed=3)
    plan = WalkPlan(length=8, cap=24, backend="sharded", capacity="auto",
                    strict_drops=True)
    eng = WalkEngine.build(g, plan)
    assert isinstance(eng.capacity, int) and 1 <= eng.capacity <= g.n
    res = eng.run(seed=5)
    assert res.stats.dropped == 0
