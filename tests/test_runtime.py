"""Fault tolerance: round resume bit-equality; balance diagnostics;
end-to-end node2vec quality."""
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import rmat
from repro.core.node2vec import Node2VecConfig, node2vec
from repro.runtime.balance import shard_balance
from repro.runtime.fault_tolerance import WalkRoundRunner


def _cfg(rounds=3):
    return Node2VecConfig(p=0.5, q=2.0, walk_length=8, num_walks=rounds,
                          dim=16, seed=11)


def test_rounds_resume_bit_identical(tmp_path, small_graph):
    g = small_graph
    cfg = _cfg()
    # uninterrupted run
    r_full = list(WalkRoundRunner(g, cfg).rounds())
    # interrupted run: complete 2 rounds, "crash", resume with a NEW runner
    ck = Checkpointer(str(tmp_path))
    runner = WalkRoundRunner(g, cfg, checkpointer=ck)
    it = runner.rounds()
    got = [next(it), next(it)]
    del it, runner      # crash
    ck.wait()
    resumed = WalkRoundRunner(g, cfg, checkpointer=Checkpointer(
        str(tmp_path)))
    r_resumed = list(resumed.rounds())
    assert len(r_resumed) == cfg.num_walks
    for a, b in zip(r_full, r_resumed):
        assert np.array_equal(a, b)


def test_balance_capped_work_bounded(skewed_graph):
    rep = shard_balance(skewed_graph, num_shards=8, cap=24)
    assert rep.capped_imbalance <= rep.edge_imbalance + 1e-9
    assert rep.capped_imbalance < 1.6  # bounded post-cap imbalance


def test_node2vec_end_to_end_quality():
    """Fig. 6 proxy at test scale: embeddings linearly separate SBM
    communities far above chance."""
    g, labels = rmat.sbm_labeled(n=240, num_communities=3, p_in=0.09,
                                 p_out=0.004, seed=2)
    cfg = Node2VecConfig(p=1.0, q=0.5, walk_length=16, num_walks=3, window=4,
                         dim=24, epochs=2, batch_size=2048, seed=0)
    emb = node2vec(g, cfg)
    rng = np.random.default_rng(0)
    idx = rng.permutation(g.n)
    tr, te = idx[:g.n // 2], idx[g.n // 2:]
    y = np.eye(3)[labels]
    w, *_ = np.linalg.lstsq(emb[tr], y[tr], rcond=None)
    acc = ((emb[te] @ w).argmax(1) == labels[te]).mean()
    assert acc > 0.65, acc
