"""Fault tolerance: round resume bit-equality (barrier and pipelined);
balance diagnostics; end-to-end node2vec quality."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import rmat
from repro.core.node2vec import Node2VecConfig, node2vec
from repro.runtime.balance import shard_balance
from repro.runtime.fault_tolerance import WalkRoundRunner


def _cfg(rounds=3):
    return Node2VecConfig(p=0.5, q=2.0, walk_length=8, num_walks=rounds,
                          dim=16, seed=11)


def test_rounds_resume_bit_identical(tmp_path, small_graph):
    g = small_graph
    cfg = _cfg()
    # uninterrupted run
    r_full = list(WalkRoundRunner(g, cfg).rounds())
    # interrupted run: complete 2 rounds, "crash", resume with a NEW runner
    ck = Checkpointer(str(tmp_path))
    runner = WalkRoundRunner(g, cfg, checkpointer=ck)
    it = runner.rounds()
    got = [next(it), next(it)]
    del it, runner      # crash
    ck.wait()
    resumed = WalkRoundRunner(g, cfg, checkpointer=Checkpointer(
        str(tmp_path)))
    r_resumed = list(resumed.rounds())
    assert len(r_resumed) == cfg.num_walks
    for a, b in zip(r_full, r_resumed):
        assert np.array_equal(a, b)


def test_rounds_resume_pipelined_fused(tmp_path, small_graph):
    """Resume with the pipeline flag on the fused backend (persistent VMEM
    kernel): bit-identical rounds and clean dropped accounting."""
    cfg = Node2VecConfig(p=0.5, q=2.0, walk_length=6, num_walks=3,
                         backend="fused", pipeline=True, seed=7)
    full = WalkRoundRunner(small_graph, cfg)
    assert full.engine._fused_persistent()       # kernel path is live
    r_full = list(full.rounds())
    ck = Checkpointer(str(tmp_path))
    runner = WalkRoundRunner(small_graph, cfg, checkpointer=ck)
    it = runner.rounds()
    next(it), next(it)
    del it, runner      # crash after 2 rounds
    ck.wait()
    resumed = WalkRoundRunner(small_graph, cfg,
                              checkpointer=Checkpointer(str(tmp_path)))
    r_resumed = list(resumed.rounds())
    for a, b in zip(r_full, r_resumed):
        assert np.array_equal(a, b)
    assert resumed.stats_summary()["dropped"] == 0


PIPELINE_RESUME_SCRIPT = textwrap.dedent("""
    import os, sys, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core import rmat
    from repro.core.node2vec import Node2VecConfig
    from repro.runtime.fault_tolerance import WalkRoundRunner

    tmp = sys.argv[1]
    warnings.simplefilter("ignore", RuntimeWarning)
    g = rmat.skew(4, k=8, avg_degree=16, seed=3)
    # starved capacity so drops are non-zero: the resume must preserve the
    # cumulative dropped accounting, not just the walks
    cfg = Node2VecConfig(p=0.5, q=2.0, walk_length=8, num_walks=3,
                         mode="approx_always", approx_eps=5e-2, cap=24,
                         capacity=2, backend="sharded", pipeline=True,
                         seed=11)
    full = WalkRoundRunner(g, cfg)
    r_full = list(full.rounds())
    assert full.total_dropped > 0, full.total_dropped
    runner = WalkRoundRunner(g, cfg, checkpointer=Checkpointer(tmp))
    it = runner.rounds()
    next(it), next(it)
    runner.ckpt.wait()
    del it, runner      # crash mid-pipeline, after 2 of 3 rounds
    resumed = WalkRoundRunner(g, cfg, checkpointer=Checkpointer(tmp))
    r_resumed = list(resumed.rounds())
    assert len(r_resumed) == cfg.num_walks
    for a, b in zip(r_full, r_resumed):
        assert np.array_equal(a, b)
    # rounds 0-1 drops come back from the checkpoint meta, round 2 reruns
    assert resumed.total_dropped == full.total_dropped, (
        resumed.total_dropped, full.total_dropped)
    assert resumed.stats_summary()["dropped"] == full.total_dropped
    print("OK", full.total_dropped)
""")


@pytest.mark.slow
def test_rounds_resume_pipelined_sharded(tmp_path):
    """Kill a pipelined sharded run (2 fake devices) between rounds; the
    resumed runner reproduces the same walks AND the same cumulative
    WalkStats.dropped accounting (carried in checkpoint meta)."""
    r = subprocess.run(
        [sys.executable, "-c", PIPELINE_RESUME_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_balance_capped_work_bounded(skewed_graph):
    rep = shard_balance(skewed_graph, num_shards=8, cap=24)
    assert rep.capped_imbalance <= rep.edge_imbalance + 1e-9
    assert rep.capped_imbalance < 1.6  # bounded post-cap imbalance


def test_node2vec_end_to_end_quality():
    """Fig. 6 proxy at test scale: embeddings linearly separate SBM
    communities far above chance."""
    g, labels = rmat.sbm_labeled(n=240, num_communities=3, p_in=0.09,
                                 p_out=0.004, seed=2)
    cfg = Node2VecConfig(p=1.0, q=0.5, walk_length=16, num_walks=3, window=4,
                         dim=24, epochs=2, batch_size=2048, seed=0)
    emb = node2vec(g, cfg)
    rng = np.random.default_rng(0)
    idx = rng.permutation(g.n)
    tr, te = idx[:g.n // 2], idx[g.n // 2:]
    y = np.eye(3)[labels]
    w, *_ = np.linalg.lstsq(emb[tr], y[tr], rcond=None)
    acc = ((emb[te] @ w).argmax(1) == labels[te]).mean()
    assert acc > 0.65, acc
