"""Roofline math: term definitions, traffic model, model-FLOPs accounting."""
import numpy as np
import pytest

from repro import configs
from repro.roofline import analysis as roof
from repro.roofline import traffic


def test_roofline_terms_per_device_semantics():
    rl = roof.Roofline(arch="x", shape="train_4k", mesh="m", chips=256,
                       hlo_flops=197e12,     # exactly one second of compute
                       hlo_bytes=819e9,      # one second of HBM
                       coll_bytes=50e9,      # one second of ICI
                       coll_by_op={}, model_flops=197e12 * 256 * 0.5)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 1.0) < 1e-9
    assert abs(rl.t_collective - 1.0) < 1e-9
    assert abs(rl.useful_ratio - 0.5) < 1e-9
    assert abs(rl.roofline_fraction - 0.5) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = configs.get_config("yi-6b")
    n = cfg.active_param_count()
    train = roof.model_flops_for(cfg, "train", 4096, 256)
    assert abs(train - 6 * n * 4096 * 256) / train < 1e-9
    decode = roof.model_flops_for(cfg, "decode", 32768, 128)
    assert abs(decode - 2 * n * 128) / decode < 1e-9


def test_moe_active_flops_smaller_than_total():
    cfg = configs.get_config("mixtral-8x22b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_traffic_model_monotonic_in_batch(kind):
    cfg = configs.get_config("yi-6b")
    mesh = {"data": 16, "model": 16}
    small = traffic.analytic_bytes(cfg, kind, 4096, 64, mesh)["total"]
    big = traffic.analytic_bytes(cfg, kind, 4096, 256, mesh)["total"]
    assert big >= small


def test_traffic_decode_is_weights_plus_cache():
    cfg = configs.get_config("yi-6b")
    mesh = {"data": 16, "model": 16}
    t = traffic.analytic_bytes(cfg, "decode", 32768, 128, mesh)
    assert t["attn_s2"] == 0.0
    assert t["total"] >= t["weights"] + t["cache"]


def test_traffic_flash_attention_removes_s2_term():
    cfg = configs.get_config("yi-6b")
    mesh = {"data": 16, "model": 16}
    base = traffic.analytic_bytes(cfg, "prefill", 32768, 32, mesh)
    flash = traffic.analytic_bytes(cfg, "prefill", 32768, 32, mesh,
                                   flash_attention=True)
    assert base["attn_s2"] > 0 and flash["attn_s2"] == 0
    assert flash["total"] < base["total"]


def test_traffic_swa_caps_score_term():
    """Mixtral's sliding window bounds the S^2 term to S*W."""
    full = configs.get_config("yi-6b")
    swa = configs.get_config("mixtral-8x22b")
    mesh = {"data": 16, "model": 16}
    t_full = traffic.analytic_bytes(full, "prefill", 32768, 32, mesh)
    t_swa = traffic.analytic_bytes(swa, "prefill", 32768, 32, mesh)
    # per attention layer, SWA's score traffic is window/seq of full
    per_full = t_full["attn_s2"] / 32
    per_swa = t_swa["attn_s2"] / 56
    assert per_swa < per_full * (4096 / 32768) * 3  # heads/batch factors