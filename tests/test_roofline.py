"""Roofline math: term definitions, traffic model, model-FLOPs accounting."""
import numpy as np
import pytest

from repro import configs
from repro.roofline import analysis as roof
from repro.roofline import traffic


def test_roofline_terms_per_device_semantics():
    rl = roof.Roofline(arch="x", shape="train_4k", mesh="m", chips=256,
                       hlo_flops=197e12,     # exactly one second of compute
                       hlo_bytes=819e9,      # one second of HBM
                       coll_bytes=50e9,      # one second of ICI
                       coll_by_op={}, model_flops=197e12 * 256 * 0.5)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 1.0) < 1e-9
    assert abs(rl.t_collective - 1.0) < 1e-9
    assert abs(rl.useful_ratio - 0.5) < 1e-9
    assert abs(rl.roofline_fraction - 0.5) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = configs.get_config("yi-6b")
    n = cfg.active_param_count()
    train = roof.model_flops_for(cfg, "train", 4096, 256)
    assert abs(train - 6 * n * 4096 * 256) / train < 1e-9
    decode = roof.model_flops_for(cfg, "decode", 32768, 128)
    assert abs(decode - 2 * n * 128) / decode < 1e-9


def test_moe_active_flops_smaller_than_total():
    cfg = configs.get_config("mixtral-8x22b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_traffic_model_monotonic_in_batch(kind):
    cfg = configs.get_config("yi-6b")
    mesh = {"data": 16, "model": 16}
    small = traffic.analytic_bytes(cfg, kind, 4096, 64, mesh)["total"]
    big = traffic.analytic_bytes(cfg, kind, 4096, 256, mesh)["total"]
    assert big >= small


def test_traffic_decode_is_weights_plus_cache():
    cfg = configs.get_config("yi-6b")
    mesh = {"data": 16, "model": 16}
    t = traffic.analytic_bytes(cfg, "decode", 32768, 128, mesh)
    assert t["attn_s2"] == 0.0
    assert t["total"] >= t["weights"] + t["cache"]


def test_traffic_flash_attention_removes_s2_term():
    cfg = configs.get_config("yi-6b")
    mesh = {"data": 16, "model": 16}
    base = traffic.analytic_bytes(cfg, "prefill", 32768, 32, mesh)
    flash = traffic.analytic_bytes(cfg, "prefill", 32768, 32, mesh,
                                   flash_attention=True)
    assert base["attn_s2"] > 0 and flash["attn_s2"] == 0
    assert flash["total"] < base["total"]


def test_traffic_swa_caps_score_term():
    """Mixtral's sliding window bounds the S^2 term to S*W."""
    full = configs.get_config("yi-6b")
    swa = configs.get_config("mixtral-8x22b")
    mesh = {"data": 16, "model": 16}
    t_full = traffic.analytic_bytes(full, "prefill", 32768, 32, mesh)
    t_swa = traffic.analytic_bytes(swa, "prefill", 32768, 32, mesh)
    # per attention layer, SWA's score traffic is window/seq of full
    per_full = t_full["attn_s2"] / 32
    per_swa = t_swa["attn_s2"] / 56
    assert per_swa < per_full * (4096 / 32768) * 3  # heads/batch factors

def test_walk_overlap_model_barrier_fully_exposed():
    m = traffic.walk_overlap_model(8, 64, 24, 20, walkers_per_shard=64,
                                   pipeline=False)
    assert m["exposed_bytes"] == m["total_bytes"] > 0
    assert m["efficiency"] == 0.0


def test_walk_overlap_model_pipeline_hides_bytes():
    """Pipelined: only the prologue is structurally un-hidable, so exposed
    bytes drop strictly below the barrier baseline and efficiency > 0 —
    while per-superstep totals stay at the barrier level (two half-capacity
    exchanges)."""
    barrier = traffic.walk_overlap_model(8, 64, 24, 20, walkers_per_shard=64,
                                         pipeline=False)
    pipe = traffic.walk_overlap_model(8, 32, 24, 20, walkers_per_shard=64,
                                      pipeline=True)
    assert pipe["total_bytes"] == barrier["total_bytes"]
    assert 0 < pipe["exposed_bytes"] < barrier["exposed_bytes"]
    assert pipe["efficiency"] > 0
    # more compute per cohort -> more hiding capacity -> less exposure
    big = traffic.walk_overlap_model(8, 32, 24, 20, walkers_per_shard=4096,
                                     pipeline=True)
    assert big["exposed_bytes"] < pipe["exposed_bytes"]
    assert big["efficiency"] > pipe["efficiency"]


def test_walk_overlap_model_degenerate_cases():
    # single shard / single step: nothing on the wire either way
    for shards, length in ((1, 20), (8, 1)):
        for pipeline in (False, True):
            m = traffic.walk_overlap_model(shards, 32, 24, length,
                                           walkers_per_shard=16,
                                           pipeline=pipeline)
            assert m == {"total_bytes": 0, "exposed_bytes": 0,
                         "efficiency": 0.0}
    # exposure never exceeds the wire total, even for tiny cohorts
    m = traffic.walk_overlap_model(2, 1, 24, 2, walkers_per_shard=1,
                                   pipeline=True)
    assert 0 <= m["exposed_bytes"] <= m["total_bytes"]
