"""Distributed walk engine == single-device reference, bit-exact — run in
subprocesses so each case gets its own fake device count (jax locks device
count at first init)."""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import rmat
    from repro.engine import WalkEngine, WalkPlan

    g = rmat.{family}
    plan = WalkPlan(p={p}, q={q}, length=10, mode="{mode}",
                    approx_eps=5e-2, cap={cap})
    ref = WalkEngine.build(g, plan).run(seed=3).walks
    mesh = Mesh(np.array(jax.devices()), ("rw",))
    import dataclasses
    sh = WalkEngine.build(g, dataclasses.replace(plan, backend="sharded"),
                          mesh=mesh).run(seed=3)
    assert sh.stats.dropped == 0, sh.stats.dropped
    assert np.array_equal(ref, sh.walks[:g.n]), "walks differ"
    print("OK", ref.shape)
""")


def _run(n, family, cap, p, q, mode):
    code = SCRIPT.format(n=n, family=family, cap=cap, p=p, q=q, mode=mode)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.parametrize("devices", [2, 8])
def test_distributed_equals_reference_exact(devices):
    _run(devices, "wec(8, avg_degree=12, seed=1)", 16, 0.5, 2.0, "exact")


def test_distributed_equals_reference_approx():
    _run(8, "skew(4, k=9, avg_degree=20, seed=3)", 24, 2.0, 0.5, "approx")


def test_distributed_equals_reference_approx_always():
    """Beyond-paper approx_always mode: distributed == reference bit-exact."""
    _run(8, "skew(4, k=9, avg_degree=20, seed=3)", 24, 0.5, 2.0,
         "approx_always")


def test_distributed_fn_base_layout():
    # cap=None -> FN-Base (no hot set): exercises the pure request/response
    # path with max-degree-wide rows
    _run(4, "wec(7, avg_degree=10, seed=2)", None, 1.0, 1.0, "exact")


def test_elastic_device_count_invariance():
    """The SAME walks regardless of shard count — the elastic-rescale
    guarantee (device-count-independent RNG + vertex-keyed state)."""
    out = {}
    for n in (2, 8):
        code = SCRIPT.format(n=n, family="wec(8, avg_degree=12, seed=1)",
                             cap=16, p=0.5, q=2.0, mode="exact")
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600,
                           env={"PYTHONPATH": "src",
                                "PATH": "/usr/bin:/bin", "HOME": "/root",
                                "JAX_PLATFORMS": "cpu"})
        assert r.returncode == 0, r.stderr[-3000:]
    # both already compared against the SAME single-device reference ->
    # transitively identical across device counts.
