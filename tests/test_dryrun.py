"""Dry-run machinery: HLO collective parser + small-mesh lower/compile for
one arch per family (subprocess: needs its own fake device count)."""
import subprocess
import sys
import textwrap

import pytest

from repro.roofline.analysis import collective_bytes, extrapolate


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %all-reduce.1 = f32[16,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %all-gather.2 = bf16[32,1024]{1,0} all-gather(%y), replica_groups=[4,8]<=[32], dimensions={0}
  %reduce-scatter.3 = f32[8,128]{1,0} reduce-scatter(%z), replica_groups=[2,4]<=[8]
  %all-to-all.4 = s32[64,64]{1,0} all-to-all(%w), replica_groups=[1,64]<=[64]
  %cp = f32[100]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %all-gather-done.9 = bf16[32,1024]{1,0} all-gather-done(%ag)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 4096 * 4
    assert out["all-gather"] == 32 * 1024 * 2 // 8      # operand = result/k
    assert out["reduce-scatter"] == 8 * 128 * 4 * 4     # operand = result*k
    assert out["all-to-all"] == 64 * 64 * 4
    assert out["collective-permute"] == 100 * 4
    assert out["_counts"]["all-gather"] == 1            # -done not counted


def test_extrapolate_linear():
    c1 = {"flops": 10.0, "bytes": 100.0, "nested": {"x": 1.0}}
    c2 = {"flops": 16.0, "bytes": 130.0, "nested": {"x": 3.0}}
    c8 = extrapolate(c1, c2, 8)
    assert c8["flops"] == 10 + 7 * 6
    assert c8["bytes"] == 100 + 7 * 30
    assert c8["nested"]["x"] == 1 + 7 * 2


SMALL_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax
    from repro import configs
    from repro.launch.dryrun import lower_cell
    from repro.roofline.analysis import cost_dict
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg0 = configs.get_config("{arch}")
    pattern = len(cfg0.superblock())
    cfg = dataclasses.replace(cfg0, num_layers=pattern,
                              enc_layers=min(cfg0.enc_layers, 1))
    comp, low, secs = lower_cell(cfg, "{kind}", {seq}, {batch}, mesh, 4)
    assert cost_dict(comp.cost_analysis()).get("flops", 0) > 0
    txt = comp.as_text()
    print("OK", comp.memory_analysis().argument_size_in_bytes)
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch,kind,seq,batch", [
    ("yi-6b", "train", 256, 8),
    ("mixtral-8x22b", "train", 256, 8),
    ("jamba-v0.1-52b", "decode", 1024, 8),
    ("mamba2-370m", "train", 256, 8),
    ("seamless-m4t-medium", "prefill", 256, 8),
    ("llama-3.2-vision-11b", "train", 256, 8),
])
def test_small_mesh_lower_compile(arch, kind, seq, batch):
    """Every family lowers + compiles on a 3-axis (pod, data, model) mesh —
    the small-scale replica of the production multi-pod dry-run."""
    code = SMALL_MESH_SCRIPT.format(arch=arch, kind=kind, seq=seq,
                                    batch=batch)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
