"""Flash-attention Pallas kernel vs materialized-scores oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention_op
from repro.kernels.ref import flash_attention_ref


def _check(B, S, H, KV, dh, window, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(B, S, H, dh)).astype(dtype)
    k = rng.normal(size=(B, S, KV, dh)).astype(dtype)
    v = rng.normal(size=(B, S, KV, dh)).astype(dtype)
    got = np.asarray(flash_attention_op(*map(jnp.asarray, (q, k, v)),
                                        window=window))
    ke, ve = np.repeat(k, H // KV, 2), np.repeat(v, H // KV, 2)
    want = np.stack([np.asarray(flash_attention_ref(
        jnp.asarray(np.swapaxes(q[b], 0, 1)),
        jnp.asarray(np.swapaxes(ke[b], 0, 1)),
        jnp.asarray(np.swapaxes(ve[b], 0, 1)), window=window))
        for b in range(B)])
    want = np.swapaxes(want, 1, 2)
    np.testing.assert_allclose(got, want, atol=3e-3, rtol=3e-3)


@pytest.mark.parametrize("B,S,H,KV,dh,window", [
    (2, 128, 4, 2, 32, 0),      # GQA causal
    (1, 256, 2, 2, 64, 0),      # MHA causal
    (2, 256, 4, 1, 32, 64),     # MQA + sliding window
    (1, 96, 3, 3, 16, 0),       # ragged (padding path)
    (1, 128, 2, 2, 128, 0),     # full lane width
])
def test_flash_matches_reference(B, S, H, KV, dh, window):
    _check(B, S, H, KV, dh, window)


def test_flash_bf16():
    rng = np.random.default_rng(1)
    q = rng.normal(size=(1, 128, 2, 32)).astype(np.float32)
    k = rng.normal(size=(1, 128, 2, 32)).astype(np.float32)
    v = rng.normal(size=(1, 128, 2, 32)).astype(np.float32)
    got = flash_attention_op(jnp.asarray(q, jnp.bfloat16),
                             jnp.asarray(k, jnp.bfloat16),
                             jnp.asarray(v, jnp.bfloat16))
    ref = flash_attention_op(*map(jnp.asarray, (q, k, v)))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("B,S,H,dh,seed", [
    (1, 64, 1, 16, 0), (2, 64, 4, 32, 1), (1, 128, 2, 16, 2),
    (2, 128, 1, 32, 3), (1, 192, 4, 16, 0), (2, 192, 2, 32, 1),
])
def test_flash_property(B, S, H, dh, seed):
    _check(B, S, H, H, dh, 0, seed=seed)
