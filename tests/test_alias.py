"""Vose alias sampling: exactness of the table + distribution of draws."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alias import alias_sample, build_alias, build_alias_rows


@pytest.mark.parametrize("k", [1, 2, 3, 5, 8, 13, 21, 40])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_alias_table_preserves_distribution(k, seed):
    """Vose invariant: sum over slots of P(slot drawn) == w_i / sum(w)."""
    rng = np.random.default_rng(1000 * k + seed)
    w = rng.uniform(0.01, 100.0, size=k).astype(np.float64)
    prob, alias = build_alias(w)
    # P(i) = (prob[i] + sum_{j: alias[j]==i} (1-prob[j])) / k
    p = prob.astype(np.float64).copy()
    implied = p / k
    for j in range(k):
        implied[alias[j]] += (1.0 - p[j]) / k
    np.testing.assert_allclose(implied, w / w.sum(), atol=1e-6)


def test_alias_rows_pad_slots_never_sampled():
    w = np.zeros((2, 8), np.float32)
    w[0, :3] = [1.0, 2.0, 3.0]
    w[1, :1] = [5.0]
    prob, alias = build_alias_rows(w)
    # live tables occupy only the first deg slots
    key = jax.random.PRNGKey(0)
    for i, deg in enumerate((3, 1)):
        draws = jax.vmap(lambda k: alias_sample(
            k, jnp.asarray(prob[i]), jnp.asarray(alias[i]), deg))(
            jax.random.split(key, 500))
        assert int(jnp.max(draws)) < deg


def test_alias_sample_distribution():
    w = np.array([1.0, 2.0, 4.0, 8.0], np.float32)
    prob, alias = build_alias(w)
    key = jax.random.PRNGKey(1)
    n = 20000
    draws = jax.vmap(lambda k: alias_sample(
        k, jnp.asarray(prob), jnp.asarray(alias), 4))(
        jax.random.split(key, n))
    counts = np.bincount(np.asarray(draws), minlength=4) / n
    np.testing.assert_allclose(counts, w / w.sum(), atol=0.02)
