"""Data pipeline: prefetch iterator + sharded batches."""
import time

import numpy as np
import pytest

from repro.data.pipeline import PrefetchIterator


def test_prefetch_preserves_order():
    it = PrefetchIterator(iter(range(20)), prefetch=4)
    assert list(it) == list(range(20))


def test_prefetch_overlaps():
    def slow_gen():
        for i in range(5):
            time.sleep(0.05)
            yield i

    it = PrefetchIterator(slow_gen(), prefetch=4)
    time.sleep(0.30)  # producer should have finished by now
    t0 = time.time()
    out = list(it)
    assert out == list(range(5))
    assert time.time() - t0 < 0.15  # items were prefetched


def test_prefetch_propagates_errors():
    def bad_gen():
        yield 1
        raise ValueError("boom")

    it = PrefetchIterator(bad_gen(), prefetch=2)
    assert next(it) == 1
    with pytest.raises(ValueError):
        list(it)
