"""Delta ingestion layer: DeltaBatch, apply_delta_csr, GraphStore (ISSUE 9).

The tentpole guarantee at the host level: applying a DeltaBatch through the
shard-local CSR patch produces exactly the graph a dict-of-dicts oracle
computes, touching only the shards the batch's rows live in — in-place when
edge counts are conserved, shard-local rebuild otherwise, never a
whole-graph re-sort. GraphStore wraps that with versioning, relabel id
mapping, and csr-directory persistence.
"""
import os
import warnings

import numpy as np
import pytest

from repro.core.graph import CSRGraph
from repro.core.walk import reset_deprecation_warnings
from repro.data import open_graph
from repro.data.deltas import DeltaBatch, apply_delta_csr, zipf_churn
from repro.data.ingest import (Dataset, _edgelist_cache_key, _load_dataset,
                               load_dataset, load_graph)
from repro.data.store import GraphStore

SPEC = "wec:k=6,deg=8,seed=1"          # 64 vertices, cheap


# --------------------------------------------------------------------------
# DeltaBatch.build normalization
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [0.0, -1.0, np.nan, np.inf])
def test_build_rejects_bad_weights(bad):
    with pytest.raises(ValueError, match="finite and > 0"):
        DeltaBatch.build(add=([0, 1], [2, 3], [1.0, bad]))


def test_build_rejects_length_mismatch():
    with pytest.raises(ValueError, match="length mismatch"):
        DeltaBatch.build(add=([0, 1], [2]))


def test_build_drops_self_loops():
    b = DeltaBatch.build(add=([0, 3, 1], [0, 3, 2]), remove=([5], [5]))
    assert b.num_add == 2               # only (1, 2) survives, symmetrized
    assert b.num_remove == 0
    assert set(zip(b.add_src.tolist(), b.add_dst.tolist())) == {(1, 2), (2, 1)}


def test_build_symmetrizes_by_default():
    b = DeltaBatch.build(add=([4], [7], [2.5]), remove=([1], [2]))
    assert set(zip(b.add_src.tolist(), b.add_dst.tolist())) == {(4, 7), (7, 4)}
    assert np.all(b.add_wgt == np.float32(2.5))
    assert set(zip(b.rem_src.tolist(), b.rem_dst.tolist())) == {(1, 2), (2, 1)}
    d = DeltaBatch.build(add=([4], [7]), undirected=False)
    assert list(zip(d.add_src.tolist(), d.add_dst.tolist())) == [(4, 7)]


def test_build_dedups_last_occurrence_wins():
    b = DeltaBatch.build(add=([0, 0, 0], [1, 1, 1], [1.0, 2.0, 3.0]))
    assert b.num_add == 2               # (0,1) + (1,0), deduped
    assert np.all(b.add_wgt == np.float32(3.0))
    r = DeltaBatch.build(remove=([2, 2], [5, 5]))
    assert r.num_remove == 2            # (2,5) + (5,2)


def test_build_sorted_per_src():
    b = DeltaBatch.build(add=([9, 1, 5, 1], [0, 8, 2, 3]), undirected=False)
    key = b.add_src * 100 + b.add_dst
    assert np.all(np.diff(key) > 0)


def test_check_rejects_out_of_range_ids():
    b = DeltaBatch.build(add=([0], [63]))
    b.check(64)                          # fits
    with pytest.raises(ValueError, match="outside"):
        b.check(63)


def test_num_edges_counts_both_directions():
    b = DeltaBatch.build(add=([0], [1]), remove=([2], [3]))
    assert b.num_edges == b.num_add + b.num_remove == 4


# --------------------------------------------------------------------------
# apply_delta_csr vs a dict-of-dicts oracle
# --------------------------------------------------------------------------

def _to_dict(g: CSRGraph):
    d = [dict() for _ in range(g.n)]
    for u in range(g.n):
        lo, hi = int(g.row_ptr[u]), int(g.row_ptr[u + 1])
        for v, w in zip(np.asarray(g.col[lo:hi]), np.asarray(g.wgt[lo:hi])):
            d[u][int(v)] = np.float32(w)
    return d


def _oracle_apply(d, batch: DeltaBatch):
    """Removals first, then upserts — the documented batch semantics."""
    removed = missing = 0
    for u, v in zip(batch.rem_src.tolist(), batch.rem_dst.tolist()):
        if v in d[u]:
            del d[u][v]
            removed += 1
        else:
            missing += 1
    updated = added = 0
    for u, v, w in zip(batch.add_src.tolist(), batch.add_dst.tolist(),
                       batch.add_wgt.tolist()):
        if v in d[u]:
            updated += 1
        else:
            added += 1
        d[u][v] = np.float32(w)
    return removed, missing, updated, added


def _assert_matches_oracle(g: CSRGraph, d):
    assert g.m == sum(len(row) for row in d)
    for u in range(g.n):
        lo, hi = int(g.row_ptr[u]), int(g.row_ptr[u + 1])
        cols = np.asarray(g.col[lo:hi])
        assert np.all(np.diff(cols) > 0), f"row {u} not sorted-unique"
        assert cols.tolist() == sorted(d[u])
        assert np.asarray(g.wgt[lo:hi]).tolist() == \
            [float(d[u][int(v)]) for v in cols]


def _random_batch(g: CSRGraph, rng, n_add=20, n_rem=15):
    """adds mix fresh pairs with weight bumps; removals hit real edges."""
    e = rng.choice(g.m, size=n_rem, replace=False)
    rem_src = np.searchsorted(np.asarray(g.row_ptr), e, side="right") - 1
    rem_dst = np.asarray(g.col)[e].astype(np.int64)
    add_src = rng.integers(0, g.n, size=n_add)
    add_dst = rng.integers(0, g.n, size=n_add)
    add_w = rng.uniform(0.5, 2.0, size=n_add).astype(np.float32)
    return DeltaBatch.build(add=(add_src, add_dst, add_w),
                            remove=(rem_src, rem_dst))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("num_shards", [1, 7, 64])
def test_apply_matches_dict_oracle(seed, num_shards):
    g = open_graph(SPEC).graph
    d = _to_dict(g)
    rng = np.random.default_rng(seed)
    for _ in range(2):                  # sequential batches compose
        batch = _random_batch(g, rng)
        rm, ms, up, ad = _oracle_apply(d, batch)
        g, rep = apply_delta_csr(g, batch, num_shards=num_shards)
        assert (rep.edges_removed, rep.removed_missing,
                rep.edges_updated, rep.edges_added) == (rm, ms, up, ad)
        assert rep.m_after == g.m
        _assert_matches_oracle(g, d)
        # the invalidation contract: affected == exactly the delta rows
        rows = np.unique(np.concatenate([batch.add_src, batch.rem_src]))
        assert np.array_equal(rep.affected, rows)
        assert np.array_equal(rep.affected_shards,
                              np.unique(rows // rep.n_local))


def test_empty_batch_is_identity():
    g = open_graph(SPEC).graph
    out, rep = apply_delta_csr(g, DeltaBatch.build())
    assert out is g
    assert rep.num_affected == 0 and rep.delta_edges == 0 and rep.in_place
    assert rep.shard_fraction == 0.0


def test_weight_only_update_splices_in_place():
    g = open_graph(SPEC).graph
    u = int(np.argmax(g.deg))
    nb = g.neighbors(u)[:3].astype(np.int64)
    batch = DeltaBatch.build(add=(np.full(3, u), nb, np.full(3, 9.0)))
    col_buf = g.col                      # the arrays themselves must be kept
    out, rep = apply_delta_csr(g, batch)
    assert out is g and rep.in_place
    assert out.col is col_buf
    assert rep.edges_updated == batch.num_add and rep.edges_added == 0
    lo = int(g.row_ptr[u])
    row = dict(zip(np.asarray(g.col[lo:lo + int(g.deg[u])]).tolist(),
                   np.asarray(g.wgt[lo:lo + int(g.deg[u])]).tolist()))
    assert all(row[int(v)] == 9.0 for v in nb)


def test_allow_in_place_false_copies():
    g = open_graph(SPEC).graph
    u = int(np.argmax(g.deg))
    v = int(g.neighbors(u)[0])
    batch = DeltaBatch.build(add=([u], [v], [9.0]))
    out, rep = apply_delta_csr(g, batch, allow_in_place=False)
    assert out is not g and not rep.in_place
    assert float(np.asarray(g.wgt)[int(g.row_ptr[u])
                                   + g.neighbors(u).tolist().index(v)]) != 9.0


def test_readonly_arrays_fall_back_out_of_place():
    g = open_graph(SPEC).graph
    for a in (g.row_ptr, g.col, g.wgt):
        a.flags.writeable = False
    before = (g.col.copy(), g.wgt.copy(), g.row_ptr.copy())
    u = int(np.argmax(g.deg))
    nb = g.neighbors(u)[:2].astype(np.int64)
    out, rep = apply_delta_csr(
        g, DeltaBatch.build(add=(np.full(2, u), nb, np.full(2, 3.0))))
    assert out is not g and not rep.in_place
    assert np.array_equal(g.col, before[0])       # source untouched
    assert np.array_equal(g.wgt, before[1])
    assert np.array_equal(g.row_ptr, before[2])
    assert float(out.wgt[int(out.row_ptr[u])
                         + out.neighbors(u).tolist().index(int(nb[0]))]) == 3.0


def test_growth_rebuild_only_touches_affected_shards():
    """Out-of-place path: unaffected shards are block copies of the source
    (identical bytes), only affected shards' segments differ."""
    g = open_graph(SPEC).graph
    u = 5
    fresh = [v for v in range(g.n) if v != u
             and v not in set(g.neighbors(u).tolist())][:4]
    out, rep = apply_delta_csr(
        g, DeltaBatch.build(add=(np.full(4, u), fresh)), num_shards=16)
    assert not rep.in_place and rep.m_after == g.m + 8
    aff = set(rep.affected_shards.tolist())
    n_local = rep.n_local
    for s in range(rep.num_shards):
        lo_v, hi_v = s * n_local, min((s + 1) * n_local, g.n)
        if s in aff or hi_v <= lo_v:
            continue
        src = slice(int(g.row_ptr[lo_v]), int(g.row_ptr[hi_v]))
        dst = slice(int(out.row_ptr[lo_v]), int(out.row_ptr[hi_v]))
        assert np.array_equal(np.asarray(g.col[src]),
                              np.asarray(out.col[dst]))
        assert np.array_equal(np.asarray(g.wgt[src]),
                              np.asarray(out.wgt[dst]))


# --------------------------------------------------------------------------
# GraphStore: versioning, relabel mapping, persistence
# --------------------------------------------------------------------------

def test_store_version_bumps_per_batch():
    st = open_graph(SPEC)
    assert st.version == 0
    st.apply(DeltaBatch.build(add=([0], [9], [1.5])))
    assert st.version == 1
    rep = st.apply([DeltaBatch.build(add=([1], [9])),
                    DeltaBatch.build(remove=([1], [9]))])
    assert st.version == 3
    assert rep.edges_added == 2 and rep.edges_removed == 2   # merged report
    assert st.last_report is rep


def test_store_rejects_stale_base_version():
    st = open_graph(SPEC)
    pinned = DeltaBatch.build(add=([0], [1]), base_version=0)
    st.apply(pinned)                    # matches version 0
    with pytest.raises(ValueError, match="stale"):
        st.apply(DeltaBatch.build(add=([2], [3]), base_version=0))


def test_store_apply_input_validation():
    st = open_graph(SPEC)
    with pytest.raises(TypeError, match="DeltaBatch"):
        st.apply([("not", "a", "batch")])
    with pytest.raises(ValueError, match="at least one"):
        st.apply([])


def test_store_meta():
    st = open_graph(SPEC)
    m = st.meta
    assert m["spec"] == SPEC and m["version"] == 0
    assert m["n"] == st.graph.n and m["m"] == st.graph.m
    assert m["relabeled"] is False and m["has_labels"] is False


def test_open_graph_accepts_every_source_kind():
    st = open_graph(SPEC)
    assert open_graph(st) is st                       # passthrough
    g = st.graph
    st2 = open_graph(g)
    assert st2.graph is g and st2.perm is None
    ds = _load_dataset(SPEC)
    assert open_graph(ds).graph is ds.graph
    with pytest.raises(TypeError, match="spec string"):
        open_graph(123)


def test_relabel_store_remaps_deltas_through_frozen_perm():
    st = open_graph(SPEC + ",relabel=degree")
    perm = st.perm
    assert perm is not None and st.meta["relabeled"]
    u, v = 3, 11                                      # ORIGINAL ids
    rep = st.apply(DeltaBatch.build(add=([u], [v], [7.0])))
    pu, pv = int(perm[u]), int(perm[v])
    assert set(rep.affected.tolist()) == {pu, pv}     # internal-id space
    row = st.graph.neighbors(pu)
    lo = int(st.graph.row_ptr[pu])
    w = float(np.asarray(st.graph.wgt)[lo + row.tolist().index(pv)])
    assert w == 7.0


def test_remap_resorts_after_permutation():
    """Regression: a degree relabel can invert id order, so remap must
    re-sort — apply_delta_csr slices the batch per shard by searchsorted
    on src and silently corrupts on unsorted input."""
    b = DeltaBatch.build(add=([0, 1], [2, 3], [1.0, 2.0]),
                         remove=([0], [3]))
    perm = np.array([9, 8, 7, 6, 5, 4, 3, 2, 1, 0], np.int64)  # reverse
    r = b.remap(perm)
    for s, d in ((r.add_src, r.add_dst), (r.rem_src, r.rem_dst)):
        key = s * 10 + d
        assert np.all(np.diff(key) > 0)
    # weights followed their edges through the re-sort
    w = dict(zip(zip(r.add_src.tolist(), r.add_dst.tolist()),
                 r.add_wgt.tolist()))
    assert w[(9, 7)] == 1.0 and w[(8, 6)] == 2.0


def test_cache_key_folds_graph_version(tmp_path):
    p = tmp_path / "edges.txt"
    p.write_text("0 1\n1 2\n")
    k0 = _edgelist_cache_key(str(p), {}, graph_version=0)
    assert _edgelist_cache_key(str(p), {}, graph_version=0) == k0
    assert _edgelist_cache_key(str(p), {}, graph_version=1) != k0
    assert _edgelist_cache_key(str(p), {"relabel": "degree"}) != k0


def test_store_save_reopen_roundtrip(tmp_path):
    st = open_graph(SPEC + ",relabel=degree")
    st.apply(DeltaBatch.build(add=([0], [5], [2.0])))
    st.apply(DeltaBatch.build(remove=([0], [5])))
    d = st.save(str(tmp_path / "g"))

    st2 = open_graph(f"csr:{d}")
    assert st2.version == st.version == 2
    assert np.array_equal(st2.perm, st.perm)
    assert np.array_equal(np.asarray(st2.graph.row_ptr),
                          np.asarray(st.graph.row_ptr))
    assert np.array_equal(np.asarray(st2.graph.col),
                          np.asarray(st.graph.col))
    assert np.array_equal(np.asarray(st2.graph.wgt),
                          np.asarray(st.graph.wgt))
    # memmapped reload is read-only: further deltas must fall back to the
    # out-of-place path, not crash on the splice
    u = int(np.argmax(st2.graph.deg))
    v = int(st2.graph.neighbors(u)[0])
    rep = st2.apply(DeltaBatch.build(add=([u], [v], [4.0])))
    assert not rep.in_place and st2.version == 3


def test_store_save_restores_labels(tmp_path):
    st = open_graph("sbm:n=60,c=3,pin=0.2,pout=0.02,seed=1")
    assert st.labels is not None
    d = st.save(str(tmp_path / "g"))
    st2 = open_graph(f"csr:{d}")
    assert np.array_equal(np.asarray(st2.labels), np.asarray(st.labels))


# --------------------------------------------------------------------------
# deprecated one-shot shims
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shim", [load_graph, load_dataset])
def test_legacy_loaders_warn_once_pointing_at_open_graph(shim):
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning, match="open_graph"):
        shim(SPEC)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        shim(SPEC)                       # second call: silent
    reset_deprecation_warnings()


def test_legacy_loaders_still_return_the_goods():
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        g = load_graph(SPEC)
    assert isinstance(g, CSRGraph)
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        ds = load_dataset(SPEC)
    assert isinstance(ds, Dataset) and ds.graph.n == g.n
    reset_deprecation_warnings()


# --------------------------------------------------------------------------
# zipf churn stream
# --------------------------------------------------------------------------

def test_zipf_churn_yields_valid_applicable_batches():
    st = open_graph(SPEC)
    n = st.graph.n
    batches = list(zipf_churn(st.graph, num_batches=4, batch_edges=10,
                              seed=3))
    assert len(batches) == 4
    for b in batches:
        b.check(n)                       # endpoints in range
        assert b.num_edges > 0
        rep = st.apply(b)                # applies cleanly, graph stays valid
        assert rep.m_after == st.graph.m
    for u in range(n):                   # rows still sorted-unique
        lo, hi = int(st.graph.row_ptr[u]), int(st.graph.row_ptr[u + 1])
        assert np.all(np.diff(np.asarray(st.graph.col[lo:hi])) > 0)


def test_zipf_churn_top_confines_shard_invalidation():
    """top=K on a degree-relabeled graph keeps every event inside the id
    prefix [0, K) — the property the update benchmark's <=10%-of-shards
    gate is built on (deg non-increasing => stable degree rank == id)."""
    g = open_graph("wec:k=8,deg=12,seed=1,relabel=degree").graph
    st = open_graph(g)                   # raw CSRGraph: no second remap
    K = 32
    for b in zipf_churn(g, num_batches=3, batch_edges=12, seed=5, top=K):
        for a in (b.add_src, b.add_dst, b.rem_src, b.rem_dst):
            assert a.size == 0 or int(a.max()) < K
        rep = st.apply(b)
        assert int(rep.affected.max()) < K
        assert rep.shard_fraction <= -(-K // rep.n_local) / rep.num_shards


def test_zipf_churn_weight_updates_flag():
    g = open_graph(SPEC).graph
    st = open_graph(g)
    (b,) = list(zipf_churn(g, num_batches=1, batch_edges=8, seed=2,
                           add_fraction=1.0, weight_updates=False))
    rep = st.apply(b)
    assert rep.edges_updated == 0        # adds avoid live edges
    assert rep.edges_added == b.num_add
