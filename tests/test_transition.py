"""2nd-order transition probabilities vs python-set oracle + FN-Approx
bound correctness (paper Eq. 2-3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import PAD_ID, CSRGraph, PaddedGraph
from repro.core.transition import (approx_gap, brute_force_probs, membership,
                                   unnormalized_probs)


def _random_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32) + 0.1
    return CSRGraph.from_edges(n, src, dst, w)


@pytest.mark.parametrize("n,m,seed", [
    (4, 6, 0), (4, 80, 1), (24, 6, 2), (8, 30, 3), (12, 50, 4), (16, 70, 5),
    (20, 40, 6), (24, 80, 7), (6, 15, 8), (10, 25, 10),
])
@pytest.mark.parametrize("pq", [(0.5, 2.0), (2.0, 0.5), (1.0, 1.0),
                                (4.0, 0.25)])
def test_probs_match_oracle(n, m, seed, pq):
    p, q = pq
    g = _random_graph(n, m, seed)
    pg = PaddedGraph.build(g)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        v = int(rng.integers(0, n))
        if g.deg[v] == 0:
            continue
        nb = g.neighbors(v)
        u = int(nb[rng.integers(0, len(nb))])
        probs = np.asarray(unnormalized_probs(
            pg.adj[v], pg.wgt[v], jnp.int32(u), pg.adj[u], p, q))
        total = probs.sum()
        oracle = brute_force_probs(g, u, v, p, q)
        for slot, x in enumerate(np.asarray(pg.adj[v])):
            if x == PAD_ID:
                assert probs[slot] == 0.0
            else:
                np.testing.assert_allclose(probs[slot] / total,
                                           oracle[int(x)], atol=1e-5)


def test_membership_with_pads():
    prev = jnp.asarray([2, 5, 9, PAD_ID, PAD_ID], jnp.int32)
    cand = jnp.asarray([1, 2, 9, 10, PAD_ID], jnp.int32)
    got = np.asarray(membership(prev, cand))
    assert list(got) == [False, True, True, False, False]


@pytest.mark.parametrize("n,m,seed", [
    (4, 20, 0), (4, 150, 1), (30, 20, 2), (10, 60, 3), (15, 90, 4),
    (20, 120, 5), (25, 150, 6), (30, 150, 7), (8, 40, 8), (12, 75, 0),
])
@pytest.mark.parametrize("pq", [(0.5, 2.0), (2.0, 0.5), (1.0, 4.0)])
def test_approx_bounds_contain_true_probs(n, m, seed, pq):
    """Paper Eq. 2-3 (generalized): every actual transition prob for a
    non-u candidate lies within [LB-ish, UB-ish]; we verify the *gap*
    computed from scalars bounds the true spread of non-u probabilities."""
    p, q = pq
    g = _random_graph(n, m, seed)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        v = int(rng.integers(0, n))
        if g.deg[v] < 3:
            continue
        nb = g.neighbors(v)
        u = int(nb[rng.integers(0, len(nb))])
        oracle = brute_force_probs(g, u, v, p, q)
        non_u = [pr for x, pr in oracle.items() if x != u]
        w = g.weights(v)
        gap = float(approx_gap(jnp.int32(g.deg[u]), jnp.int32(g.deg[v]),
                               jnp.float32(w.min()), jnp.float32(w.max()),
                               p, q))
        spread = max(non_u) - min(non_u)
        assert spread <= gap + 1e-6, (spread, gap)
