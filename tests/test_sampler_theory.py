"""Theory quality gate: node2vec stationary distribution (closed form).

The node2vec walk is a 2nd-order Markov chain; lifted to the directed-edge
state space (u, v) it is 1st-order with transition

    T[(u, v), (v, x)] = alpha_pq(u, x) * w(v, x) / Z(u, v)

(Meng & Masuda, "Analysis of node2vec random walks on networks", Proc. R.
Soc. A 2020). On a small graph the stationary distribution over edges is
computable exactly — power iteration over T built from the
``brute_force_probs`` oracle — and the marginal node visit frequency
``f(v) = sum_u pi(u, v)`` must match empirical visit counts from the walk
engine within CI bounds. For p = q = 1 the chain drops to a plain weighted
random walk whose stationary node law is strength-proportional
(f(v) ∝ sum_x w(v, x)), giving an independent closed form.

This gates the *sampler itself* (any backend would do — the parity battery
pins the backends to each other; this pins them to the math).
"""
import numpy as np
import pytest

from repro.core.graph import CSRGraph
from repro.core.transition import brute_force_probs
from repro.engine import WalkEngine, WalkPlan

# enough samples that 6-sigma CI bounds are tight but tolerant of the
# autocorrelation of successive steps within one walk
WALKERS = 128
LENGTH = 200
BURN = 60


def weighted_cycle(n: int = 8) -> CSRGraph:
    src = np.arange(n)
    dst = (src + 1) % n
    w = 1.0 + (src % 3).astype(np.float32)        # weights 1, 2, 3 repeating
    return CSRGraph.from_edges(n, src, dst, w)


def weighted_star(leaves: int = 6) -> CSRGraph:
    src = np.zeros(leaves, np.int64)
    dst = np.arange(1, leaves + 1)
    w = np.linspace(1.0, 3.0, leaves).astype(np.float32)
    return CSRGraph.from_edges(leaves + 1, src, dst, w)


def edge_chain_stationary(g: CSRGraph, p: float, q: float):
    """Exact stationary node visit frequencies via the directed-edge chain,
    plus the chain's integrated autocorrelation time tau = (1+l2)/(1-l2)
    (l2 = second-largest eigenvalue modulus of T) — the factor by which
    correlated within-walk samples are discounted when forming CI bounds."""
    edges = [(int(u), int(v)) for u in range(g.n) for v in g.neighbors(u)]
    idx = {e: i for i, e in enumerate(edges)}
    T = np.zeros((len(edges), len(edges)))
    for (u, v), i in idx.items():
        for x, prob in brute_force_probs(g, u, v, p, q).items():
            T[i, idx[(v, x)]] = prob
    assert np.allclose(T.sum(axis=1), 1.0)
    pi = np.full(len(edges), 1.0 / len(edges))
    for _ in range(5000):
        nxt = pi @ T
        if np.abs(nxt - pi).sum() < 1e-12:
            pi = nxt
            break
        pi = nxt
    f = np.zeros(g.n)
    for (u, v), i in idx.items():
        f[v] += pi[i]
    lam = np.sort(np.abs(np.linalg.eigvals(T)))[::-1]
    l2 = min(float(lam[1]), 0.995)
    tau = max((1.0 + l2) / (1.0 - l2), 1.0)
    return f / f.sum(), tau


def empirical_visits(g: CSRGraph, p: float, q: float, seed: int) -> np.ndarray:
    plan = WalkPlan(p=p, q=q, length=LENGTH, backend="reference")
    eng = WalkEngine.build(g, plan)
    starts = (np.arange(WALKERS) % g.n).astype(np.int32)
    walks = eng.run(starts=starts, seed=seed,
                    walker_ids=np.arange(WALKERS, dtype=np.int32)).walks
    tail = np.asarray(walks)[:, BURN:]
    counts = np.bincount(tail.ravel(), minlength=g.n).astype(np.float64)
    return counts / counts.sum(), tail.size


def assert_within_ci(emp, theory, n_samples, tau, label):
    # successive steps of one walk are correlated with integrated
    # autocorrelation time tau; the WALKERS chains are independent, so the
    # effective sample count is walkers * (per-walk samples / tau)
    per_walk = n_samples / WALKERS
    n_eff = WALKERS * max(per_walk / tau, 1.0)
    sigma = np.sqrt(theory * (1.0 - theory) / n_eff)
    err = np.abs(emp - theory)
    assert (err <= 6.0 * sigma + 2.0 / n_eff).all(), (
        label, emp, theory, err / np.maximum(sigma, 1e-12))
    tv = 0.5 * err.sum()
    assert tv < max(2.0 * sigma.sum(), 0.02), (label, tv, sigma.sum())


@pytest.mark.parametrize("p,q", [(1.0, 1.0), (0.25, 4.0), (4.0, 0.25),
                                 (2.0, 0.5)])
def test_cycle_stationary_distribution(p, q):
    g = weighted_cycle()
    theory, tau = edge_chain_stationary(g, p, q)
    emp, n = empirical_visits(g, p, q, seed=17)
    assert_within_ci(emp, theory, n, tau, f"cycle p={p} q={q}")


@pytest.mark.parametrize("p,q", [(1.0, 1.0), (0.5, 2.0)])
def test_star_stationary_distribution(p, q):
    g = weighted_star()
    theory, tau = edge_chain_stationary(g, p, q)
    emp, n = empirical_visits(g, p, q, seed=23)
    assert_within_ci(emp, theory, n, tau, f"star p={p} q={q}")


@pytest.mark.parametrize("make", [weighted_cycle, weighted_star])
def test_pq1_reduces_to_strength_proportional(make):
    """p = q = 1: the edge chain's node marginal must equal the classical
    strength-proportional law — an independent closed form the chain
    construction itself is checked against."""
    g = make()
    strength = np.array([g.weights(v).sum() for v in range(g.n)], np.float64)
    closed_form = strength / strength.sum()
    chain, tau = edge_chain_stationary(g, 1.0, 1.0)
    assert np.allclose(chain, closed_form, atol=1e-9)
    emp, n = empirical_visits(g, 1.0, 1.0, seed=31)
    assert_within_ci(emp, closed_form, n, tau, f"pq1 {make.__name__}")
