"""CSR graph / RMAT / PaddedGraph invariants (unit + seeded random sweeps)."""
import numpy as np
import pytest

from repro.core import rmat
from repro.core.graph import PAD_ID, CSRGraph, PaddedGraph


def test_csr_from_edges_basic():
    g = CSRGraph.from_edges(4, [0, 1, 2], [1, 2, 3])
    assert g.n == 4 and g.m == 6  # symmetrized
    assert list(g.neighbors(1)) == [0, 2]
    assert g.deg.sum() == g.m


def test_csr_drops_self_loops_and_dupes():
    g = CSRGraph.from_edges(3, [0, 0, 0, 1], [0, 1, 1, 2])
    assert g.m == 4  # (0,1),(1,0),(1,2),(2,1)
    assert 0 not in g.neighbors(0)


@pytest.mark.parametrize("n,m,seed", [
    (2, 1, 0), (2, 120, 1), (3, 7, 2), (5, 30, 3), (8, 64, 4), (13, 13, 5),
    (20, 90, 0), (27, 1, 1), (33, 50, 2), (40, 120, 3),
])
def test_csr_invariants_random(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(n, src, dst)
    # rows sorted, within range, symmetric, no self loops
    for v in range(n):
        nb = g.neighbors(v)
        assert np.all(np.diff(nb) > 0)
        assert np.all((nb >= 0) & (nb < n))
        assert v not in nb
        for x in nb:
            assert v in g.neighbors(int(x))


def test_trim_top_weights():
    rng = np.random.default_rng(0)
    g = rmat.wec(7, avg_degree=16, seed=0)
    t = g.trim_top_weights(5)
    assert t.deg.max() <= 5 + 5  # out-trim + incoming from others... directed
    # trim is per-out-vertex: every vertex keeps at most 5 out-edges
    counts = t.row_ptr[1:] - t.row_ptr[:-1]
    assert counts.max() <= 5


def test_transition_table_bytes_matches_eq1():
    g = CSRGraph.from_edges(3, [0, 1], [1, 2])
    d = g.deg.astype(np.int64)
    assert g.transition_table_bytes() == 8 * int((d * d).sum())


@pytest.mark.parametrize("fam,k,avg", [("er", 8, 10), ("wec", 8, 50)])
def test_rmat_families(fam, k, avg):
    g = getattr(rmat, fam)(k, avg_degree=avg, seed=0)
    assert g.n == 1 << k
    # avg degree within 40% of target (dedup removes some)
    assert abs(g.m / g.n - avg) / avg < 0.4


def test_skew_increases_max_degree():
    maxdeg = [rmat.skew(s, k=9, avg_degree=20, seed=0).max_degree
              for s in (1, 3, 5)]
    assert maxdeg[0] < maxdeg[1] < maxdeg[2]


def test_padded_graph_exact_rows(small_graph):
    g = small_graph
    pg = PaddedGraph.build(g)
    assert pg.cap == g.max_degree
    for v in [0, 1, g.n // 2, g.n - 1]:
        nb = g.neighbors(v)
        row = np.asarray(pg.adj[v])
        assert np.array_equal(row[:len(nb)], nb)
        assert np.all(row[len(nb):] == PAD_ID)


def test_padded_graph_hot_cache_covers_tail(small_graph):
    g = small_graph
    cap = 16
    pg = PaddedGraph.build(g, cap=cap)
    deg = np.asarray(pg.deg)
    hot_pos = np.asarray(pg.hot_pos)
    # invariant: every vertex with degree > cap is hot
    assert np.all(hot_pos[deg > cap] >= 0)
    # hot rows are full-degree exact
    hot_ids = np.asarray(pg.hot_ids)
    for i, v in enumerate(hot_ids):
        nb = g.neighbors(int(v))
        row = np.asarray(pg.hot_adj[i])
        assert np.array_equal(row[:len(nb)], nb)


def test_padded_graph_no_hot_sentinel(small_graph):
    pg = PaddedGraph.build(small_graph)  # cap = max degree -> no hot set
    assert np.asarray(pg.hot_ids)[0] == PAD_ID
    assert np.all(np.asarray(pg.hot_pos) == -1)
