"""Optimizers, schedules, gradient utilities (incl. int8 error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.grad_utils import (clip_by_global_norm, compressed_psum,
                                    dequantize_int8, global_norm,
                                    init_error_feedback, quantize_int8,
                                    accumulate_gradients)
from repro.optim.optimizers import adam, apply_updates, sgd
from repro.optim.schedules import inverse_sqrt, linear_warmup_cosine


def test_adam_matches_reference_math():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    opt = adam(lr=0.01, b1=0.9, b2=0.999, eps=1e-8)
    state = opt.init(p)
    updates, state = opt.update(g, state, p)
    # closed form at t=1: m_hat = g, v_hat = g^2 -> u = -lr * g/(|g|+eps)
    want = -0.01 * np.sign(np.asarray(g["w"]))
    np.testing.assert_allclose(np.asarray(updates["w"]), want, atol=1e-4)


def test_adam_converges_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam(lr=0.1)
    state = opt.init(p)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, p)   # d/dx x^2
        u, state = opt.update(g, state, p)
        p = apply_updates(p, u)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_sgd_momentum():
    p = {"w": jnp.zeros(2)}
    opt = sgd(lr=0.1, momentum=0.9)
    state = opt.init(p)
    g = {"w": jnp.ones(2)}
    u1, state = opt.update(g, state, p)
    u2, state = opt.update(g, state, p)
    np.testing.assert_allclose(np.asarray(u2["w"]),
                               np.asarray(u1["w"]) * 1.9, rtol=1e-6)


def test_weight_decay_only_on_matrices():
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    opt = adam(lr=0.1, weight_decay=0.5)
    state = opt.init(p)
    u, _ = opt.update({"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))},
                      state, p)
    assert float(jnp.abs(u["w"]).sum()) > 0     # decayed
    assert float(jnp.abs(u["b"]).sum()) == 0    # not decayed


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) < 0.2
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 0.1
    assert float(s(jnp.asarray(100))) < 0.01
    r = inverse_sqrt(1.0, 100)
    assert abs(float(r(jnp.asarray(100))) - 1.0) < 0.02
    assert float(r(jnp.asarray(400))) < 0.55


@pytest.mark.parametrize("seed", range(10))
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=128).astype(np.float32))
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_compensates():
    """With error feedback, the *cumulative* compressed gradient converges to
    the cumulative true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=64).astype(np.float32))}
    res = init_error_feedback(g_true)
    acc_comp = jnp.zeros(64)
    steps = 50
    for _ in range(steps):
        comp, res = compressed_psum(g_true, res)
        acc_comp = acc_comp + comp["w"]
    acc_true = g_true["w"] * steps
    # cumulative difference == final residual -> bounded by one quant step
    np.testing.assert_allclose(np.asarray(acc_comp + res["w"]),
                               np.asarray(acc_true), rtol=1e-3, atol=1e-3)


def test_accumulate_gradients_matches_full_batch():
    w = jnp.asarray([1.0, 2.0])

    def loss_fn(p, batch):
        return jnp.mean((batch @ p) ** 2)

    batch = jnp.asarray(np.random.default_rng(0).normal(size=(8, 2)),
                        jnp.float32)
    l1, g1 = jax.value_and_grad(loss_fn)(w, batch)
    l2, g2 = accumulate_gradients(loss_fn, w, batch, num_microbatches=4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)
