"""Checkpointer: roundtrip, atomic commit, async, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones(3)},
            "opt": [jnp.zeros(2), jnp.asarray(3)]}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(5, tree, meta={"note": "x"})
    got, meta = ck.restore(tree)
    assert meta["step"] == 5 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_multiple_steps(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    ck.save(7, _tree())
    assert ck.latest_step() == 7
    _, meta = ck.restore(_tree(), step=1)
    assert meta["step"] == 1


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 3


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp staging dir must never be selected by restore."""
    ck = Checkpointer(str(tmp_path))
    ck.save(2, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ck.latest_step() == 2


def test_restore_empty_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_tree())
