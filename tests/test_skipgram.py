"""SGNS training + corpus pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.skipgram import (SGNSConfig, init_params, sgns_loss,
                                 train_step)
from repro.data.corpus import (NegativeSampler, sgns_pairs,
                               walks_to_lm_tokens, walks_to_sgns_batches)
from repro.optim.optimizers import adam


def test_sgns_loss_decreases():
    cfg = SGNSConfig(vocab=50, dim=16, negatives=3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adam(0.05)
    state = opt.init(params)
    rng = np.random.default_rng(0)
    # fixed co-occurrence structure: i with i+1 mod 50
    c = rng.integers(0, 50, 512)
    batch = {"center": jnp.asarray(c, jnp.int32),
             "pos": jnp.asarray((c + 1) % 50, jnp.int32),
             "neg": jnp.asarray(rng.integers(0, 50, (512, 3)), jnp.int32)}
    first = float(sgns_loss(params, batch["center"], batch["pos"],
                            batch["neg"]))
    for _ in range(30):
        params, state, loss = train_step(params, state, batch, opt)
    assert float(loss) < first * 0.7


@pytest.mark.parametrize("w,l,window", [
    (2, 2, 1), (2, 30, 6), (10, 2, 3), (3, 5, 1), (4, 8, 2), (5, 12, 4),
    (7, 20, 5), (8, 3, 6), (9, 25, 2), (10, 30, 1),
])
def test_sgns_pairs_window_property(w, l, window):
    walks = np.arange(w * l, dtype=np.int32).reshape(w, l)  # all distinct
    c, x = sgns_pairs(walks, window)
    # count: for each row, sum over offsets 1..min(window, l-1) of 2*(l-off)
    expect = w * sum(2 * (l - off) for off in range(1, min(window, l - 1) + 1))
    assert len(c) == expect
    # symmetry: (a, b) present iff (b, a) present
    pairs = set(zip(c.tolist(), x.tolist()))
    assert all((b, a) in pairs for a, b in pairs)


def test_negative_sampler_distribution():
    walks = np.concatenate([np.zeros(300, np.int32),
                            np.ones(100, np.int32),
                            np.full(25, 2, np.int32)])[None, :]
    s = NegativeSampler(walks, vocab=3, power=0.75)
    rng = np.random.default_rng(0)
    draws = s.sample(rng, 40000)
    freq = np.bincount(draws, minlength=3) / 40000
    target = np.array([300., 100., 25.]) ** 0.75
    np.testing.assert_allclose(freq, target / target.sum(), atol=0.02)


def test_batches_shapes_and_validity():
    walks = np.random.default_rng(0).integers(0, 40, (8, 10)).astype(np.int32)
    batches = list(walks_to_sgns_batches(walks, 40, window=3, negatives=4,
                                         batch_size=64, epochs=1))
    assert all(b["center"].shape == (64,) for b in batches)
    assert all(b["neg"].shape == (64, 4) for b in batches)
    total_valid = sum(int(b["valid"].sum()) for b in batches)
    c, x = sgns_pairs(walks, 3)
    assert total_valid == len(c)


def test_walks_to_lm_tokens():
    walks = np.arange(60, dtype=np.int32).reshape(4, 15)
    toks = walks_to_lm_tokens(walks, seq_len=8)
    assert toks.shape == (7, 8)
    toks_bos = walks_to_lm_tokens(walks, seq_len=8, bos=999)
    assert (toks_bos == 999).sum() == 4 or toks_bos.shape[0] * 8 <= 64
