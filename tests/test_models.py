"""Model zoo: per-arch smoke tests + decode/train consistency + layer units."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import model as M
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig

pytestmark = pytest.mark.slow   # LM-lowering smoke sweeps dominate runtime

ARCHS = configs.list_archs()


def _batch(cfg, b, s, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if cfg.enc_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_audio_frames, cfg.d_model)),
            jnp.float32)
    if cfg.cross_every and not cfg.enc_layers:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/backward, finite loss + grads, shapes."""
    cfg = configs.smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, 2, 16, rng)
    logits = M.forward_train(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_matches_forward(arch):
    """prefill + teacher-forced serve_step logits == full forward logits.

    This validates every decode path (KV cache + rope offsets, SWA ring,
    mamba recurrence vs chunked SSD, cross-attn memory) against the train
    path to float tolerance.

    MoE archs run with dropless capacity (cf = E): capacity-factor token
    dropping legitimately differs between the train-time and decode-time
    group sizes (Switch semantics), so equality holds only without drops.
    """
    cfg = configs.smoke_config(arch)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.moe_experts))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s, extra = 2, 12, 4
    batch = _batch(cfg, b, s + extra, rng)
    full_logits = M.forward_train(cfg, params, batch)          # [B, S+E, V]
    prompt = {k: (v[:, :s] if k in ("tokens", "labels") else v)
              for k, v in batch.items()}
    logits, caches = M.prefill(cfg, params, prompt, max_len=s + extra)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, s - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(extra):
        tok = batch["tokens"][:, s + i]
        logits, caches = M.serve_step(cfg, params, tok,
                                      jnp.asarray(s + i, jnp.int32), caches)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, s + i]),
                                   atol=2e-3, rtol=2e-3,
                                   err_msg=f"{arch} step {i}")


def test_swa_equals_full_when_window_large():
    cfg = dataclasses.replace(configs.smoke_config("yi-6b"), window=0)
    cfg_w = dataclasses.replace(cfg, window=64)  # window > seq
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, 2, 16, rng)
    l1 = M.forward_train(cfg, params, batch)
    l2 = M.forward_train(cfg_w, params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_swa_masks_distant_tokens():
    """With window w, token t must be independent of tokens < t - w + 1."""
    cfg = dataclasses.replace(configs.smoke_config("mixtral-8x22b"),
                              window=4, moe_experts=0, moe_every=0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b1 = _batch(cfg, 1, 16, rng)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["tokens"] = b2["tokens"].at[0, 0].set(
        (b2["tokens"][0, 0] + 1) % cfg.vocab)  # perturb token 0
    l1 = M.forward_train(cfg, params, b1)
    l2 = M.forward_train(cfg, params, b2)
    # positions >= 8 can't see token 0 through a single window-4 layer stack
    # of depth 2 (receptive field 0..(w-1)*L = 6)
    np.testing.assert_allclose(np.asarray(l1[:, 9:]), np.asarray(l2[:, 9:]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))


def test_mamba_chunked_equals_stepwise():
    """Chunked SSD scan == token-by-token recurrence."""
    cfg = configs.smoke_config("mamba2-370m")
    p = mb.init_mamba(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 12, cfg.d_model)) * 0.3, jnp.float32)
    y_full = mb.mamba_apply(cfg, p, x)
    cache = mb.init_mamba_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(12):
        y_t, cache = mb.mamba_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=2e-3, rtol=2e-3)


def test_mamba_prefill_state_matches_decode():
    cfg = configs.smoke_config("mamba2-370m")
    p = mb.init_mamba(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 10, cfg.d_model)) * 0.3, jnp.float32)
    _, cache_pre = mb.mamba_prefill(cfg, p, x)
    cache = mb.init_mamba_cache(cfg, 1, jnp.float32)
    for t in range(10):
        _, cache = mb.mamba_decode(cfg, p, x[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(cache_pre["h"]),
                               np.asarray(cache["h"]), atol=2e-3, rtol=2e-3)
    for key in ("cx", "cb", "cc"):
        np.testing.assert_allclose(np.asarray(cache_pre[key]),
                                   np.asarray(cache[key]), atol=1e-4)


def test_moe_routing_properties():
    cfg = configs.smoke_config("mixtral-8x22b")
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y = moe_lib.moe_apply(cfg, p, x, num_groups=2)
    assert y.shape == x.shape and np.all(np.isfinite(np.asarray(y)))
    # zero input -> zero output (no biases)
    y0 = moe_lib.moe_apply(cfg, p, jnp.zeros_like(x), num_groups=2)
    assert float(jnp.abs(y0).max()) < 1e-5


def test_moe_group_invariance():
    """Same tokens, different local group count -> same result when capacity
    is not binding (cf >= E/topk guarantees room for every token)."""
    cfg = dataclasses.replace(configs.smoke_config("phi3.5-moe-42b-a6.6b"),
                              capacity_factor=4.0)
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y1 = moe_lib.moe_apply(cfg, p, x, num_groups=1)
    y2 = moe_lib.moe_apply(cfg, p, x, num_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)


def test_param_counts_match_published():
    expected = {"yi-6b": 6.1, "mixtral-8x22b": 140.6, "nemotron-4-15b": 15.6,
                "jamba-v0.1-52b": 51.5, "phi3.5-moe-42b-a6.6b": 41.9,
                "minitron-8b": 7.7, "minitron-4b": 4.2, "mamba2-370m": 0.42}
    for arch, want in expected.items():
        got = configs.get_config(arch).param_count() / 1e9
        assert abs(got - want) / want < 0.05, (arch, got, want)
    # phi3.5 active ~6.6B
    assert abs(configs.get_config("phi3.5-moe-42b-a6.6b").active_param_count()
               / 1e9 - 6.6) < 0.3
