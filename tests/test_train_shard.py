"""Sharded SGNS trainer (repro.train.shard; DESIGN.md §16).

In-process tests run on the 1-device default backend with a 1-shard mesh —
the shard_map program, sparse gathers, and lazy row-Adam all execute, just
without a second shard. Cross-shard behavior (2 table shards: bit-identity
vs the 1-shard run, collective accounting, zero retrace) runs in
subprocesses that set XLA_FLAGS before importing jax."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alias import build_alias
from repro.core.skipgram import SGNSConfig, init_params
from repro.core.walk_distributed import RW_AXIS, _shard_map
from repro.data.corpus import NegativeSampler
from repro.launch.mesh import make_table_mesh
from repro.optim.optimizers import adam_rows
from repro.train import (StreamingSGNSTrainer, pow2_bucket, shard_opt_state,
                         shard_params, table_rows, train_epoch_sharded)
from repro.train.pairs import device_negatives
from jax.sharding import PartitionSpec as P


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 2, 3, 256, 257, 1024)] == \
        [1, 2, 4, 256, 512, 1024]


def test_table_rows_pads_to_shard_multiple():
    assert table_rows(257, 1) == 257
    assert table_rows(257, 2) == 258
    assert table_rows(256, 2) == 256
    assert table_rows(10, 4) == 12


# ------------------------------------------------------- numpy oracle --
def _np_adam_rows(g, mu, nu, count, lr=0.025, b1=0.9, b2=0.999, eps=1e-8):
    """adam_rows.update in float32 numpy (count = already-incremented)."""
    f32 = np.float32
    mu = f32(b1) * mu + f32(1 - b1) * g
    nu = f32(b2) * nu + f32(1 - b2) * (g * g)
    bc1 = f32(1) - f32(b1) ** count
    bc2 = f32(1) - f32(b2) ** count
    upd = -f32(lr) * (mu / bc1) / (np.sqrt(nu / bc2) + f32(eps))
    return upd, mu, nu


def _np_sgns_rows(ci, po, no, v):
    """sgns_row_grads closed form in float64 (reference precision)."""
    sig = lambda x: 1.0 / (1.0 + np.exp(-x))
    pos = np.sum(ci * po, -1, keepdims=True)
    neg = np.sum(no * ci[:, None, :], -1)
    loss = np.logaddexp(0, -pos[:, 0]) + np.logaddexp(0, neg).sum(-1)
    cp = (sig(pos) - 1.0) * v[:, None]
    cn = sig(neg) * v[:, None]
    g_ci = cp * po + np.sum(cn[:, :, None] * no, axis=1)
    g_po = cp * ci
    g_no = cn[:, :, None] * ci[:, None, :]
    return float((loss * v).sum()), g_ci, g_po, g_no


def test_sharded_epoch_matches_numpy_reference():
    """One sharded epoch (1-shard mesh) == a numpy replay of the lazy
    row-Adam semantics: dedup per unique row, segment-sum grads in batch
    order, Adam only on touched rows. Negatives are taken from the same
    (already unit-tested) device draw so the oracle only re-derives the
    sharded math itself."""
    V, D, B, K, steps = 67, 8, 16, 3, 4
    rng = np.random.default_rng(0)
    n = steps * B - 5
    c = rng.integers(0, V, steps * B).astype(np.int32)
    x = rng.integers(0, V, steps * B).astype(np.int32)
    valid = rng.random(steps * B) < 0.9
    perm2d = rng.permutation(steps * B).astype(np.int32).reshape(steps, B)
    prob_np, alias_np = build_alias(rng.random(V) + 0.1)
    key = jax.random.PRNGKey(3)
    mesh = make_table_mesh(max_shards=1)
    opt = adam_rows(0.025)
    params = init_params(SGNSConfig(vocab=V, dim=D, negatives=K),
                         jax.random.PRNGKey(0))
    ref = {k: np.asarray(v, np.float64) for k, v in params.items()}
    params = shard_params(params, V, mesh)
    state = shard_opt_state(params, mesh)
    u_in, u_out = pow2_bucket(B), pow2_bucket(B * (1 + K))
    p2, s2, losses = train_epoch_sharded(
        params, state, jnp.asarray(c), jnp.asarray(x), jnp.asarray(valid),
        jnp.asarray(perm2d), jnp.asarray(prob_np), jnp.asarray(alias_np),
        key, mesh=mesh, opt=opt, negatives=K, backend="jnp", n_pairs=n,
        u_in=u_in, u_out=u_out)

    mu = {k: np.zeros_like(v) for k, v in ref.items()}
    nu = {k: np.zeros_like(v) for k, v in ref.items()}
    want = []
    for s in range(steps):
        idx = perm2d[s]
        v = (valid[idx] & (s * B + np.arange(B) < n)).astype(np.float64)
        neg = np.asarray(device_negatives(
            jax.random.fold_in(key, s), jnp.asarray(prob_np),
            jnp.asarray(alias_np), (B, K)))
        ci, po, no = ref["emb_in"][c[idx]], ref["emb_out"][x[idx]], \
            ref["emb_out"][neg]
        loss, g_ci, g_po, g_no = _np_sgns_rows(ci, po, no, v)
        denom = max(v.sum(), 1.0)
        want.append(loss / denom)
        uc = np.unique(c[idx])
        uo = np.unique(np.concatenate([x[idx], neg.reshape(-1)]))
        g_uc = np.zeros((uc.size, D))
        np.add.at(g_uc, np.searchsorted(uc, c[idx]), g_ci / denom)
        g_uo = np.zeros((uo.size, D))
        np.add.at(g_uo, np.searchsorted(uo, x[idx]), g_po / denom)
        np.add.at(g_uo, np.searchsorted(uo, neg.reshape(-1)),
                  g_no.reshape(B * K, -1) / denom)
        for tab, u, g in (("emb_in", uc, g_uc), ("emb_out", uo, g_uo)):
            upd, mu_n, nu_n = _np_adam_rows(g, mu[tab][u], nu[tab][u], s + 1)
            ref[tab][u] += upd
            mu[tab][u], nu[tab][u] = mu_n, nu_n
    got = jax.device_get(p2)
    np.testing.assert_allclose(got["emb_in"][:V], ref["emb_in"],
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(got["emb_out"][:V], ref["emb_out"],
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(losses), want, rtol=0, atol=1e-5)
    assert int(jax.device_get(s2.count)) == steps


@pytest.fixture(scope="module")
def tiny_graph():
    from repro.data import open_graph
    return open_graph("wec:k=7,deg=10,seed=1").graph    # 128 vertices


def _rounds(vocab, n=3, w=32, l=9, seed=5):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, (w, l)).astype(np.int32)
            for _ in range(n)]


def _sharded_trainer(vocab=129, **kw):
    base = dict(dim=16, window=3, negatives=3, batch_size=64,
                shard_tables=True, mesh=make_table_mesh(max_shards=1))
    base.update(kw)
    return StreamingSGNSTrainer(vocab, **base)


def test_sharded_fused_matches_jnp():
    """Fused Pallas backend under the sharded epoch == jnp closed form."""
    embs = {}
    for backend in ("jnp", "fused"):
        tr = _sharded_trainer(sgns_backend=backend)
        emb, _ = tr.train(iter(_rounds(129)))
        embs[backend] = np.asarray(emb)
    np.testing.assert_allclose(embs["fused"], embs["jnp"], rtol=0, atol=2e-5)


def test_sharded_streamed_matches_concat():
    """Streamed consumption == replaying the collected rounds (the dense
    trainer's bit-identity contract holds for the sharded path too)."""
    rounds = _rounds(129)
    a, _ = _sharded_trainer().train(iter(rounds))
    b, _ = _sharded_trainer().train(iter(list(rounds)))
    assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_sharded_rounds_do_not_retrace():
    """Same round shape -> ONE compile across all rounds x epochs, with
    params/opt donated through every call."""
    tr = _sharded_trainer(epochs=2, batch_size=32)   # shape unique to this
    before = train_epoch_sharded._cache_size()       # test -> fresh compile
    tr.train(iter(_rounds(129, n=4, w=24)))
    assert train_epoch_sharded._cache_size() == before + 1


def test_sharded_stats_accounting():
    """Shard/collective fields: 1 shard -> no exchange, overlap 0."""
    _, st = _sharded_trainer().train(iter(_rounds(129)))
    assert st.shards == 1
    assert st.collective_bytes == 0
    assert st.exposed_collective_bytes == 0


# ----------------------------------------- negative-sampling parity --
def test_alias_tables_match_global_sampler(tiny_graph):
    """The sharded trainer's incrementally maintained alias tables equal
    NegativeSampler's built from the full corpus at GLOBAL vocabulary —
    sharding partitions table rows, never the unigram counts."""
    tr = _sharded_trainer(vocab=tiny_graph.n)
    rounds = _rounds(tiny_graph.n, n=2)
    for r in rounds:
        tr.consume(r)
    prob, alias, _ = tr._alias_refresh(np.zeros((0, 2), np.int32))
    ref = NegativeSampler(np.concatenate(rounds, axis=0), tiny_graph.n)
    np.testing.assert_allclose(np.asarray(prob), ref.prob, rtol=0,
                               atol=1e-12)
    np.testing.assert_array_equal(np.asarray(alias), ref.alias)


def test_sharded_negative_draws_replay_single_device_stream():
    """device_negatives replicated under shard_map == the plain call: the
    draw depends only on (key, tables, shape), so the sharded trainer's
    negative stream is the single-device stream bit for bit."""
    V = 61
    prob_np, alias_np = build_alias(np.random.default_rng(1).random(V) + .1)
    prob, alias = jnp.asarray(prob_np), jnp.asarray(alias_np)
    key = jax.random.PRNGKey(9)
    mesh = make_table_mesh(max_shards=1)
    direct = device_negatives(key, prob, alias, (32, 5))
    sharded = _shard_map(
        lambda p, a, k: device_negatives(k, p, a, (32, 5)), mesh,
        in_specs=(P(), P(), P()), out_specs=P())(prob, alias, key)
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(sharded))


# -------------------------------------------------- 2-device parity --
TWO_DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.launch.mesh import make_table_mesh
    from repro.train import StreamingSGNSTrainer, train_epoch_sharded

    assert jax.device_count() == 2
    V = 257                              # odd: pad row live on both tables
    rng = np.random.default_rng(5)
    rounds = [rng.integers(0, V, (48, 9)).astype(np.int32)
              for _ in range(3)]

    for backend in ("jnp", "fused"):
        out = {{}}
        for s in (1, 2):
            tr = StreamingSGNSTrainer(
                V, dim=16, window=3, negatives=3, batch_size=64, epochs=2,
                sgns_backend=backend, shard_tables=True,
                mesh=make_table_mesh(max_shards=s))
            before = train_epoch_sharded._cache_size()
            emb, st = tr.train(iter(list(rounds)))
            # zero retrace: one compile for all 3 rounds x 2 epochs
            assert train_epoch_sharded._cache_size() == before + 1, \\
                (s, backend, train_epoch_sharded._cache_size() - before)
            assert st.shards == s
            assert (st.collective_bytes > 0) == (s > 1), st
            out[s] = (np.asarray(emb), tr.loss_history())
        assert out[1][0].tobytes() == out[2][0].tobytes(), \\
            ("emb mismatch", backend)
        assert out[1][1].tobytes() == out[2][1].tobytes(), \\
            ("loss mismatch", backend)
    print("OK")
""")

TWO_DEV_STEP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.alias import build_alias
    from repro.core.skipgram import SGNSConfig, init_params
    from repro.launch.mesh import make_table_mesh
    from repro.optim.optimizers import adam_rows
    from repro.train import (pow2_bucket, shard_opt_state, shard_params,
                             train_epoch_sharded)

    V, D, B, K, steps = 101, 8, 32, 3, 3
    rng = np.random.default_rng(2)
    n = steps * B - 7
    c = jnp.asarray(rng.integers(0, V, steps * B).astype(np.int32))
    x = jnp.asarray(rng.integers(0, V, steps * B).astype(np.int32))
    valid = jnp.asarray(rng.random(steps * B) < 0.9)
    perm2d = jnp.asarray(
        rng.permutation(steps * B).astype(np.int32).reshape(steps, B))
    prob_np, alias_np = build_alias(rng.random(V) + 0.1)
    prob, alias = jnp.asarray(prob_np), jnp.asarray(alias_np)
    key = jax.random.PRNGKey(4)
    opt = adam_rows(0.025)

    out = {{}}
    for s in (1, 2):
        mesh = make_table_mesh(max_shards=s)
        params = shard_params(
            init_params(SGNSConfig(vocab=V, dim=D, negatives=K),
                        jax.random.PRNGKey(0)), V, mesh)
        state = shard_opt_state(params, mesh)
        p2, s2, losses = train_epoch_sharded(
            params, state, c, x, valid, perm2d, prob, alias, key,
            mesh=mesh, opt=opt, negatives=K, backend="jnp", n_pairs=n,
            u_in=pow2_bucket(B), u_out=pow2_bucket(B * (1 + K)))
        got = jax.device_get(p2)
        out[s] = (got["emb_in"][:V], got["emb_out"][:V],
                  np.asarray(losses))
    for a, b in zip(out[1], out[2]):
        assert a.tobytes() == b.tobytes()
    print("OK")
""")


def _run_subprocess(code):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_two_device_streamed_bit_identity():
    """S=1 == S=2 bit for bit over a full streamed run (both backends),
    with zero retraces and collective accounting, on 2 fake devices."""
    _run_subprocess(TWO_DEV_SCRIPT.format())


@pytest.mark.slow
def test_two_device_epoch_bit_identity():
    """Single sharded epoch call: 1-shard == 2-shard tables + losses."""
    _run_subprocess(TWO_DEV_STEP_SCRIPT.format())
