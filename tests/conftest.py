"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device; the
distributed tests spawn subprocesses that set the device count themselves.

Fixtures resolve through the dataset registry so every test run exercises
the ``open_graph`` spec path (bit-identical to calling ``repro.core.rmat``
directly — asserted in tests/test_ingest.py)."""
import numpy as np
import pytest

from repro.data import open_graph


@pytest.fixture(scope="session")
def small_graph():
    return open_graph("wec:k=8,deg=12,seed=1").graph    # 256 vertices


@pytest.fixture(scope="session")
def skewed_graph():
    # 512 vertices, skewed degrees
    return open_graph("skew:s=4,k=9,deg=20,seed=3").graph
