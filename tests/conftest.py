"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device; the
distributed tests spawn subprocesses that set the device count themselves."""
import numpy as np
import pytest

from repro.core import rmat


@pytest.fixture(scope="session")
def small_graph():
    return rmat.wec(8, avg_degree=12, seed=1)          # 256 vertices


@pytest.fixture(scope="session")
def skewed_graph():
    return rmat.skew(4, k=9, avg_degree=20, seed=3)    # 512 vertices, skewed
