"""Streamed on-device SGNS training (repro.train) — parity battery.

Contracts:
* device pair-gen emits exactly the host ``sgns_pairs`` stream (order and
  all) with self-pairs masked instead of compacted;
* device alias negatives follow the unigram^0.75 distribution;
* streamed consumption (train round k-1 while round k walks) is
  bit-identical to collecting all rounds first and replaying them;
* the fused Pallas kernel behind ``train_step(backend="fused")`` matches
  the jnp autodiff path (loss trajectory and final tables);
* fixed-shape batching never retraces across rounds;
* TrainStats accounting (pairs, steps, H2D bytes) is exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.node2vec import Node2VecConfig
from repro.core.skipgram import SGNSConfig, init_params, train_step
from repro.data.corpus import NegativeSampler, sgns_pairs, \
    walks_to_sgns_batches
from repro.optim.optimizers import adam
from repro.runtime.fault_tolerance import WalkRoundRunner
from repro.train import (StreamingSGNSTrainer, device_negatives, device_pairs,
                         num_pairs)
from repro.train.stream import _train_epoch


def _cfg(**kw):
    base = dict(p=0.5, q=2.0, walk_length=10, num_walks=3, window=4,
                dim=16, negatives=3, batch_size=256, seed=0)
    base.update(kw)
    return Node2VecConfig(**base)


@pytest.fixture(scope="module")
def tiny_graph():
    from repro.data import open_graph
    return open_graph("wec:k=7,deg=10,seed=1").graph    # 128 vertices


# ------------------------------------------------------------ pair gen --
@pytest.mark.parametrize("w,l,window,seed", [
    (1, 2, 1, 0), (4, 8, 3, 1), (16, 12, 5, 2), (7, 5, 10, 3), (3, 2, 4, 4),
])
def test_device_pairs_matches_host(w, l, window, seed):
    rng = np.random.default_rng(seed)
    walks = rng.integers(0, 50, (w, l)).astype(np.int32)
    # inject dead-end self-loop tails so the validity mask is exercised
    walks[:, -1] = walks[:, -2]
    c, x, valid = jax.device_get(device_pairs(jnp.asarray(walks), window))
    assert c.shape == (num_pairs(w, l, window),)
    hc, hx = sgns_pairs(walks, window)
    # same stream, same order — the host path just compacts the mask away
    np.testing.assert_array_equal(c[valid], hc)
    np.testing.assert_array_equal(x[valid], hx)
    assert np.all(c[~valid] == x[~valid])


def test_device_negatives_distribution():
    counts = np.array([300., 100., 25.])
    from repro.core.alias import build_alias
    prob, alias = build_alias(counts ** 0.75)
    draws = np.asarray(device_negatives(
        jax.random.PRNGKey(0), jnp.asarray(prob), jnp.asarray(alias),
        (40000,)))
    freq = np.bincount(draws, minlength=3) / 40000
    target = counts ** 0.75
    np.testing.assert_allclose(freq, target / target.sum(), atol=0.02)


# ---------------------------------------------------- streamed == concat --
def test_streamed_matches_concat(tiny_graph):
    cfg = _cfg(epochs=2)   # epochs > 1 exercises the per-epoch rng fold
    streamed = StreamingSGNSTrainer.from_config(tiny_graph.n, cfg)
    emb_s, st_s = streamed.train(WalkRoundRunner(tiny_graph, cfg).rounds())

    rounds = list(WalkRoundRunner(tiny_graph, cfg).rounds())
    concat = StreamingSGNSTrainer.from_config(tiny_graph.n, cfg)
    emb_c, st_c = concat.train(iter(rounds))

    assert np.array_equal(emb_s, emb_c)        # bit-identical embeddings
    np.testing.assert_array_equal(streamed.loss_history(),
                                  concat.loss_history())
    assert st_s.steps == st_c.steps and st_s.pairs == st_c.pairs


# ------------------------------------------------------- fused backend --
def test_fused_train_step_matches_jnp():
    cfg = SGNSConfig(vocab=60, dim=24, negatives=4)
    opt = adam(0.05)
    rng = np.random.default_rng(3)
    params = {"jnp": init_params(cfg, jax.random.PRNGKey(1)),
              "fused": init_params(cfg, jax.random.PRNGKey(1))}
    states = {k: opt.init(p) for k, p in params.items()}
    losses = {"jnp": [], "fused": []}
    for step in range(5):
        c = rng.integers(0, 60, 128).astype(np.int32)
        batch = {"center": jnp.asarray(c),
                 "pos": jnp.asarray((c + 1) % 60),
                 "neg": jnp.asarray(
                     rng.integers(0, 60, (128, 4)).astype(np.int32)),
                 "valid": jnp.asarray(
                     (rng.random(128) > 0.2).astype(np.float32))}
        for backend in ("jnp", "fused"):
            params[backend], states[backend], loss = train_step(
                params[backend], states[backend], batch, opt, backend)
            losses[backend].append(float(loss))
    np.testing.assert_allclose(losses["jnp"], losses["fused"],
                               rtol=1e-4, atol=1e-4)
    for k in ("emb_in", "emb_out"):
        np.testing.assert_allclose(np.asarray(params["jnp"][k]),
                                   np.asarray(params["fused"][k]),
                                   rtol=1e-4, atol=1e-4)


def test_fused_streamed_matches_jnp_streamed(tiny_graph):
    cfg = _cfg(num_walks=2)
    rounds = list(WalkRoundRunner(tiny_graph, cfg).rounds())
    emb = {}
    for backend in ("jnp", "fused"):
        tr = StreamingSGNSTrainer.from_config(tiny_graph.n, cfg,
                                              sgns_backend=backend)
        emb[backend], _ = tr.train(iter(rounds))
    np.testing.assert_allclose(emb["jnp"], emb["fused"],
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------ compile economy --
def test_stream_rounds_do_not_retrace(tiny_graph):
    cfg = _cfg(num_walks=4)
    trainer = StreamingSGNSTrainer.from_config(tiny_graph.n, cfg)
    it = iter(list(WalkRoundRunner(tiny_graph, cfg).rounds()))
    trainer.consume(next(it))
    compiled_after_first = _train_epoch._cache_size()
    for walks in it:
        trainer.consume(walks)
    # rounds 2..4 share round 1's fixed shapes — zero new compiles
    assert _train_epoch._cache_size() == compiled_after_first


# ------------------------------------------------- host-path satellite --
def test_padded_rows_skip_negative_sampling():
    walks = np.random.default_rng(0).integers(0, 40, (6, 8)).astype(np.int32)
    window, negatives, batch_size, seed = 3, 4, 64, 7
    centers, _ = sgns_pairs(walks, window)
    n = len(centers)
    assert n % batch_size != 0          # the last batch really is padded
    batches = list(walks_to_sgns_batches(walks, 40, window, negatives,
                                         batch_size, seed=seed))
    # replay the exact rng stream: permutation, then per-batch draws sized
    # to the *live* rows only — if padded rows consumed draws, this diverges
    sampler = NegativeSampler(walks, 40)
    rng = np.random.default_rng(seed)
    rng.permutation(n)
    for lo, b in zip(range(0, n, batch_size), batches):
        live = min(batch_size, n - lo)
        np.testing.assert_array_equal(
            b["neg"][:live], sampler.sample(rng, (live, negatives)))
        assert np.all(b["neg"][live:] == 0)
        assert np.all(b["valid"][live:] == 0)


# ---------------------------------------------------------- accounting --
def test_train_stats_accounting(tiny_graph):
    cfg = _cfg(num_walks=2, epochs=2)
    rounds = list(WalkRoundRunner(tiny_graph, cfg).rounds())
    trainer = StreamingSGNSTrainer.from_config(tiny_graph.n, cfg)
    _, st = trainer.train(iter(rounds))

    want_pairs, want_steps, want_h2d = 0, 0, 0
    per_step = 4 * cfg.batch_size * (3 + cfg.negatives)
    want_h2d_concat = 0
    for w in rounds:
        hc, _ = sgns_pairs(w, cfg.window)
        want_pairs += len(hc) * cfg.epochs
        steps = -(-num_pairs(*w.shape, cfg.window) // cfg.batch_size)
        want_steps += steps * cfg.epochs
        want_h2d += w.astype(np.int32).nbytes + tiny_graph.n * 8
        want_h2d_concat += steps * cfg.epochs * per_step
    assert st.pairs == want_pairs
    assert st.steps == want_steps
    assert st.h2d_bytes == want_h2d
    assert st.h2d_bytes_concat == want_h2d_concat
    assert st.tokens == sum(w.size for w in rounds)
    assert 0.0 <= st.overlap_efficiency <= 1.0
    assert st.pairs_per_sec > 0 and st.wall_seconds > 0
