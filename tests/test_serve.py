"""Serving-layer tests (DESIGN.md §13): cache policy, coalescer semantics,
batched-vs-single bit-identity, deadline shedding, compile-shape bound.

Everything runs on a VirtualClock — time is an explicit argument through the
whole serve stack, so these tests are deterministic under any machine load.
"""
import numpy as np
import pytest

from repro.data import open_graph
from repro.engine import WalkPlan
from repro.serve import (DeadlineBatcher, EmbeddingService, ResultCache,
                         VirtualClock, hot_set_admission, prefix_admission,
                         synthetic_trace, zipf_nodes)

CAP = 24


@pytest.fixture(scope="module")
def graph():
    # relabel=degree: vertex id == degree rank, hot set == id prefix
    return open_graph("skew:s=4,k=9,deg=20,seed=3,relabel=degree").graph


def _emb(n, dim=16, seed=0):
    e = np.random.default_rng(seed).normal(size=(n, dim)).astype(np.float32)
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def _service(graph, clock, **kw):
    kw.setdefault("plan", WalkPlan(backend="reference", cap=CAP))
    kw.setdefault("cache_size", 64)
    kw.setdefault("buckets", (4, 16, 64))
    return EmbeddingService(graph, _emb(graph.n), clock=clock, **kw)


# ---------------------------------------------------------------- cache ----

def test_lru_eviction_order():
    c = ResultCache(3)
    for k in "abc":
        c.put(k, k.upper(), node=0)
    assert c.keys() == ["a", "b", "c"]
    c.get("a")                          # refresh: a becomes most recent
    c.put("d", "D", node=0)             # evicts b (LRU), not a
    assert "b" not in c and "a" in c
    assert c.keys() == ["c", "a", "d"]
    c.put("e", "E", node=0)             # evicts c
    assert c.keys() == ["a", "d", "e"]


def test_hot_prefix_admission(graph):
    deg = graph.deg
    hot = hot_set_admission(deg, CAP)
    pre = prefix_admission(int((deg > CAP).sum()))
    # relabel=degree: the FN-Cache hot set IS the contiguous id prefix,
    # so the two admission predicates agree on every vertex
    for v in range(graph.n):
        assert hot(v) == pre(v), v
    assert not hot(-1) and not hot(graph.n + 7)

    c = ResultCache(8, admit=hot)
    hot_v = 0                            # degree rank 0 == biggest hub
    cold_v = graph.n - 1
    assert deg[hot_v] > CAP > deg[cold_v]
    assert c.put(("embed", hot_v, 0), "x")        # node from tuple key
    assert not c.put(("embed", cold_v, 0), "y")   # cold: bypasses cache
    assert ("embed", hot_v, 0) in c and ("embed", cold_v, 0) not in c


def test_service_cold_queries_never_evict_hot(graph):
    clock = VirtualClock()
    svc = _service(graph, clock, cache_size=4)
    hubs = [0, 1, 2, 3]
    for v in hubs:
        svc.submit("embed", v, now=clock())
    svc.drain(now=clock())
    assert len(svc.cache) == 4
    for v in range(graph.n - 32, graph.n):        # a run of cold queries
        svc.submit("embed", v, now=clock())
        svc.drain(now=clock())
    assert sorted(k[1] for k in svc.cache.keys()) == hubs


# ------------------------------------------------------------- coalescer ----

def test_batched_matches_single(graph):
    """Coalesced batched serving is bit-identical to per-request serving,
    for plain gathers, walk-averaged embeds, and neighbor ranking."""
    clock = VirtualClock()
    svc = _service(graph, clock)
    nodes = zipf_nodes(graph.n, 32, alpha=1.1, seed=7)
    for window in (0, 4):
        batched = svc.embed(nodes, window=window)
        singles = np.stack([svc.embed(int(v), window=window)[0]
                            for v in nodes])
        np.testing.assert_array_equal(batched, singles)
    ids_b, sc_b = svc.rank_neighbors(nodes, k=6)
    for i, v in enumerate(nodes):
        ids_s, sc_s = svc.rank_neighbors(int(v), k=6)
        np.testing.assert_array_equal(ids_b[i], ids_s[0])
        np.testing.assert_array_equal(sc_b[i], sc_s[0])


def test_coalescer_determinism(graph):
    """Same request multiset, different arrival orders -> bit-identical
    per-node responses (RNG keyed on node id, never batch position)."""
    rng = np.random.default_rng(3)
    nodes = zipf_nodes(graph.n, 48, alpha=1.1, seed=5)

    def serve(order):
        clock = VirtualClock()
        svc = _service(graph, clock, cache_size=1)  # no cross-request reuse
        got = {}
        rid_to_node = {}
        for v in order:
            rid = svc.submit("embed", int(v), window=3, now=clock())
            rid_to_node[rid] = int(v)
            clock.advance(1e-4)
        for resp in svc.drain(now=clock()):
            assert not resp.expired
            got.setdefault(rid_to_node[resp.rid], []).append(resp.value)
        return got

    a = serve(nodes)
    b = serve(rng.permutation(nodes))
    assert set(a) == set(b)
    for v in a:
        for x in a[v] + b[v]:
            np.testing.assert_array_equal(x, a[v][0])


def test_deadline_expiry_under_starved_queue(graph):
    """A queue that is never pumped past its deadlines sheds every queued
    request as expired — without touching the compute path."""
    clock = VirtualClock()
    svc = _service(graph, clock)
    rids = [svc.submit("embed", int(v), deadline_s=1e-3, now=clock())
            for v in zipf_nodes(graph.n, 20, alpha=1.1, seed=0)]
    clock.advance(10.0)                  # starve past every deadline
    responses = svc.drain(now=clock())
    assert sorted(r.rid for r in responses) == sorted(rids)
    assert all(r.expired and r.value is None for r in responses)
    st = svc.stats()
    assert st.expired == 20 and st.requests == 0
    assert st.batches == 0               # shed without launching compute


def test_deadline_pulls_batch_forward():
    """A request whose deadline is within margin flushes its whole group
    immediately instead of lingering for occupancy."""
    b = DeadlineBatcher(buckets=(4, 16), linger_s=10.0, margin_s=1e-3)
    b.submit(("embed", 0), 1, deadline=100.0, now=0.0)
    assert b.due(now=0.0) == []          # lingering: no occupancy, no rush
    b.submit(("embed", 0), 2, deadline=0.5, now=0.1)
    flushes = b.due(now=0.5)             # deadline - now <= margin
    assert len(flushes) == 1
    group, live, dead = flushes[0]
    assert [r.node for r in live] == [1, 2] and dead == []


def test_compile_shape_bound(graph):
    """The jit compile set stays bounded by buckets x query groups even
    under arbitrary request sizes (pad-to-bucket, no per-size recompile)."""
    clock = VirtualClock()
    svc = _service(graph, clock, buckets=(4, 16))
    rng = np.random.default_rng(0)
    for size in rng.integers(1, 17, size=12):
        svc.embed(rng.integers(0, graph.n, size=size))
        svc.rank_neighbors(rng.integers(0, graph.n, size=size), k=5)
    kernels = {s[0] for s in svc.compiled_shapes}
    assert kernels == {"gather", "rank"}
    assert len(svc.compiled_shapes) <= 2 * len(svc.batcher.buckets)
    assert {s[1] for s in svc.compiled_shapes} <= set(svc.batcher.buckets)


# ------------------------------------------------------------ end-to-end ----

def test_trace_replay_accounts_every_request(graph):
    """Virtual-clock Zipf replay: every submitted request comes back exactly
    once (completed or expired), stats add up, hit rate is meaningful."""
    clock = VirtualClock()
    svc = _service(graph, clock)
    num = 300
    seen = set()
    for ev in synthetic_trace(graph.n, num, alpha=1.2, qps=10_000.0,
                              deadline_s=0.05, seed=0):
        clock.t = ev.t_arrival
        svc.submit(ev.kind, ev.node, k=5, deadline_s=ev.deadline_s,
                   now=clock())
        for r in svc.pump(now=clock()):
            assert r.rid not in seen
            seen.add(r.rid)
    for r in svc.drain(now=clock() + 1.0):
        assert r.rid not in seen
        seen.add(r.rid)
    st = svc.stats()
    assert st.requests + st.expired == num == len(seen)
    assert 0.0 < st.cache_hit_rate < 1.0
    assert 0.0 < st.batch_occupancy <= 1.0


def test_accepts_raw_sgns_params(graph):
    """The service takes a raw SGNS params pytree and normalizes it through
    skipgram.serving_table — same table as passing the array yourself."""
    import jax

    from repro.core.skipgram import SGNSConfig, init_params, serving_table

    params = init_params(SGNSConfig(vocab=graph.n, dim=8),
                         jax.random.PRNGKey(0))
    svc = EmbeddingService(graph, params,
                           plan=WalkPlan(backend="reference", cap=CAP))
    np.testing.assert_array_equal(np.asarray(svc.emb),
                                  serving_table(params))
    norms = np.linalg.norm(np.asarray(svc.emb), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


# --------------------------------------------------------------- warming ----

def test_warm_from_walks(graph):
    """Cache warming from walk-visit counts: admitted top-visited vertices
    land in the cache, entries are bit-identical to cold queries, and a
    subsequent submit for a warmed node is answered from cache."""
    clock = VirtualClock()
    svc = _service(graph, clock, cache_size=32)
    # skewed synthetic "last round": hubs (low ids) dominate visit counts
    walks = zipf_nodes(graph.n, 40 * 8, alpha=1.2, seed=3).reshape(40, 8)
    warmed = svc.warm_from_walks(walks, window=0)
    assert 0 < warmed <= 32
    assert len(svc.cache) == warmed
    # every warmed entry == the batched cold computation for that node
    keys = svc.cache.keys()
    nodes = np.asarray([k[1] for k in keys], np.int32)
    cold = _service(graph, VirtualClock(), cache_size=32)
    want = cold.embed(nodes, window=0)
    for key, w in zip(keys, want):
        np.testing.assert_array_equal(svc.cache.get(key), w)
    # the most-visited vertex answers from cache, no walk relaunched
    counts = np.bincount(walks.ravel(), minlength=graph.n)
    hot = int(np.argmax(counts))
    hits0 = svc.cache.hits
    svc.submit("embed", hot, now=clock())
    svc.drain(now=clock())
    assert svc.cache.hits == hits0 + 1


def test_warm_from_walks_respects_top_and_admission(graph):
    """`top` caps the warm budget below capacity; inadmissible (cold-tail)
    vertices are never warmed even when visited."""
    clock = VirtualClock()
    svc = _service(graph, clock, cache_size=16)
    walks = zipf_nodes(graph.n, 64, alpha=1.1, seed=9).reshape(8, 8)
    warmed = svc.warm_from_walks(walks, window=0, top=5)
    assert warmed <= 5 and len(svc.cache) == warmed
    if svc.cache.admit is not None:
        for _, v, _ in svc.cache.keys():
            assert svc.cache.admit(int(v))
