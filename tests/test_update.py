"""Incremental engine updates: shard-local invalidation parity (ISSUE 9).

The tentpole property: after ``WalkEngine.update(deltas)`` — which patches
only the affected rows' packed adjacency / alias tables / FN-Cache hot
entries on device — walks are **bit-identical** to a from-scratch engine
built at the same store version. Covered here for reference and fused
in-process, sharded (2 fake devices) in a subprocess, including
``relabel=degree`` stores where deltas arrive in original ids. Plus the
accounting surfaces: UpdateReport, WalkStats stamping, the runner's
between-rounds drain (bounded staleness of one in-flight round), and the
serving-side ``refresh`` (selective cache invalidation).
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data import open_graph
from repro.data.deltas import DeltaBatch, zipf_churn
from repro.engine import WalkEngine, WalkPlan, round_seed

SPEC = "wec:k=8,deg=12,seed=1"          # 256 vertices


def _churn(num_batches, seed, spec=SPEC, batch_edges=12):
    """Materialized churn batches generated against a pristine copy of
    ``spec`` — safe to apply to several independent stores."""
    return list(zipf_churn(open_graph(spec).graph, num_batches=num_batches,
                           batch_edges=batch_edges, seed=seed))


# --------------------------------------------------------------------------
# the core property: update == from-scratch rebuild, bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused"])
@pytest.mark.parametrize("cap", [None, 8])
def test_update_matches_fresh_rebuild(backend, cap):
    plan = WalkPlan(p=0.5, q=2.0, length=8, cap=cap, backend=backend)
    batches = _churn(3, seed=4)

    eng = WalkEngine.build(SPEC, plan)
    eng.update(batches[:2])
    rep = eng.update(batches[2])
    got = eng.run(seed=3).walks

    st = open_graph(SPEC)
    st.apply(batches)
    assert st.version == eng.store.version == rep.version == 3
    fresh = WalkEngine.build(st, plan).run(seed=3).walks
    assert np.array_equal(got, fresh)


@pytest.mark.parametrize("mode", ["exact", "approx"])
def test_update_matches_fresh_rebuild_relabel_degree(mode):
    """Deltas in ORIGINAL ids against a degree-relabeled store: both the
    updated engine and the fresh rebuild map through the same frozen perm."""
    spec = SPEC + ",relabel=degree"
    batches = _churn(2, seed=5)          # original-id space
    plan = WalkPlan(length=8, cap=16, mode=mode, approx_eps=5e-2)

    eng = WalkEngine.build(spec, plan)
    eng.update(batches)

    st = open_graph(spec)
    st.apply(batches)
    fresh = WalkEngine.build(st, plan).run(seed=2).walks
    assert np.array_equal(eng.run(seed=2).walks, fresh)


def test_relayout_on_hot_membership_change():
    """Growing a cold vertex past ``cap`` flips FN-Cache membership — the
    patch must fall back to a full relayout (and say so), and walks must
    still match a fresh build."""
    plan = WalkPlan(length=6, cap=8)
    eng = WalkEngine.build(SPEC, plan)
    g = eng.store.graph
    v = int(np.argmin(g.deg))
    assert int(g.deg[v]) <= 8
    fresh_nb = [u for u in range(g.n)
                if u != v and u not in set(g.neighbors(v).tolist())][:12]
    batch = DeltaBatch.build(add=(np.full(len(fresh_nb), v), fresh_nb))

    rep = eng.update(batch)
    assert rep.relayout
    assert rep.invalidated_fraction == 1.0
    assert int(eng.pg.deg[v]) > 8        # v now hot on device

    st = open_graph(SPEC)
    st.apply(batch)
    fresh = WalkEngine.build(st, plan).run(seed=9).walks
    assert np.array_equal(eng.run(seed=9).walks, fresh)


def test_weight_only_update_avoids_relayout():
    """Weight churn on existing edges (the common case): no relayout, only
    the affected shards invalidated, FN-Cache hot rows respliced in place —
    and still bit-identical to a fresh build."""
    plan = WalkPlan(length=6, cap=8)
    eng = WalkEngine.build(SPEC, plan)
    g = eng.store.graph
    hot = int(np.argmax(g.deg))
    nb = g.neighbors(hot)[:4].astype(np.int64)
    batch = DeltaBatch.build(
        add=(np.full(4, hot), nb, np.full(4, 1.7, np.float32)))

    rep = eng.update(batch)
    assert not rep.relayout
    assert rep.patch.in_place            # conserved counts -> spliced
    assert rep.hot_rows_updated >= 1     # the hub's replicated row moved
    assert 0.0 < rep.invalidated_fraction < 1.0

    st = open_graph(SPEC)
    st.apply(batch)
    fresh = WalkEngine.build(st, plan).run(seed=11).walks
    assert np.array_equal(eng.run(seed=11).walks, fresh)


def test_update_without_store_raises():
    from repro.core.graph import PaddedGraph
    pg = PaddedGraph.build(open_graph(SPEC).graph, cap=16)
    eng = WalkEngine.build(pg, WalkPlan(length=4, cap=16))
    assert eng.store is None
    with pytest.raises(ValueError, match="GraphStore"):
        eng.update(DeltaBatch.build(add=([0], [1])))


# --------------------------------------------------------------------------
# accounting surfaces
# --------------------------------------------------------------------------

def test_walkstats_stamp_version_and_churn():
    plan = WalkPlan(length=5, cap=16)
    eng = WalkEngine.build(SPEC, plan)
    s0 = eng.run(seed=0).stats
    assert s0.graph_version == 0 and s0.delta_edges == 0
    assert s0.invalidated_shard_fraction == 0.0

    rep = eng.update(_churn(2, seed=6))
    s1 = eng.run(seed=0).stats
    assert s1.graph_version == 2
    assert s1.delta_edges == rep.patch.delta_edges    # cumulative churn
    assert s1.invalidated_shard_fraction == \
        pytest.approx(rep.invalidated_fraction)

    eng.update(_churn(1, seed=7))                     # accumulates
    s2 = eng.run(seed=0).stats
    assert s2.graph_version == 3
    assert s2.delta_edges > s1.delta_edges


def test_runner_updates_land_between_rounds():
    """submit_update drains after the yield; engine.rounds has round r+1
    already in flight, so an update submitted while consuming round 0 first
    affects round 2 — and every round walks exactly one graph version."""
    from repro.core.node2vec import Node2VecConfig
    from repro.runtime.fault_tolerance import WalkRoundRunner

    g = open_graph(SPEC).graph
    hot = int(np.argmax(g.deg))
    nb = g.neighbors(hot)[:3].astype(np.int64)
    batch = DeltaBatch.build(
        add=(np.full(3, hot), nb, np.full(3, 2.2, np.float32)))

    cfg = Node2VecConfig(walk_length=6, num_walks=4, cap=16, seed=3)
    runner = WalkRoundRunner(g, cfg)
    it = runner.rounds()
    walks = [next(it)]
    runner.submit_update(batch)
    walks.extend(it)

    versions = [runner.round_stats[r].graph_version for r in range(4)]
    assert versions == [0, 0, 1, 1]
    assert len(runner.update_reports) == 1
    assert runner.update_reports[0].version == 1

    # post-update rounds match a fresh engine at version 1, same round seed
    st = open_graph(SPEC)
    st.apply(batch)
    fresh = WalkEngine.build(st, cfg.plan(None))
    for r in (2, 3):
        ref = fresh.run(seed=round_seed(cfg.seed, r)).walks
        assert np.array_equal(walks[r], ref)


def test_serve_refresh_selective_invalidation_and_parity():
    from repro.serve import EmbeddingService

    st = open_graph(SPEC)
    g = st.graph
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((g.n, 16)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    plan = WalkPlan(length=4, cap=16)
    svc = EmbeddingService(st, emb, plan=plan, cache_size=64,
                           admission=f"prefix:{g.n}")

    hot = int(np.argmax(g.deg))
    nb = g.neighbors(hot)[:2].astype(np.int64)
    affected = {hot} | {int(v) for v in nb}
    bystander = next(u for u in range(g.n) if u not in affected)

    for node in (hot, bystander):        # populate the cache via the queue
        svc.submit("embed", node, window=0)
    svc.drain()
    assert svc.cache.get(("embed", hot, 0)) is not None
    assert svc.cache.get(("embed", bystander, 0)) is not None

    batch = DeltaBatch.build(
        add=(np.full(2, hot), nb, np.full(2, 3.3, np.float32)))
    rep = svc.refresh(batch)
    assert rep["version"] == 1 and not rep["relayout"]
    assert rep["cache_entries_dropped"] >= 1
    assert 0.0 < rep["invalidated_fraction"] < 1.0
    assert svc.cache.get(("embed", hot, 0)) is None        # invalidated
    assert svc.cache.get(("embed", bystander, 0)) is not None  # kept

    # walk-window embeddings now match a service built fresh at version 1
    st2 = open_graph(SPEC)
    st2.apply(batch)
    svc2 = EmbeddingService(st2, emb, plan=plan, cache_size=64,
                            admission=f"prefix:{g.n}")
    nodes = [hot, bystander, 3, 200]
    assert np.array_equal(svc.embed(nodes, window=3),
                          svc2.embed(nodes, window=3))


# --------------------------------------------------------------------------
# sharded backend (2 fake devices, subprocess — jax pins device count)
# --------------------------------------------------------------------------

SHARDED_UPDATE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.data import open_graph
    from repro.data.deltas import zipf_churn
    from repro.engine import WalkEngine, WalkPlan

    SPEC = "wec:k=8,deg=12,seed=1,relabel=degree"
    batches = list(zipf_churn(open_graph("wec:k=8,deg=12,seed=1").graph,
                              num_batches=2, batch_edges=12, seed=7))
    plan = WalkPlan(p=0.5, q=2.0, length=8, cap=16, backend="sharded")

    eng = WalkEngine.build(SPEC, plan)
    rep = eng.update(batches)
    assert rep.version == 2
    assert 0 < rep.invalidated_device_shards <= rep.device_shards
    got = eng.run(seed=3)
    assert got.stats.dropped == 0
    assert got.stats.graph_version == 2

    st = open_graph(SPEC)
    st.apply(batches)
    fresh = WalkEngine.build(st, plan).run(seed=3)
    assert np.array_equal(got.walks, fresh.walks)

    ref_plan = WalkPlan(p=0.5, q=2.0, length=8, cap=16)
    ref = WalkEngine.build(st, ref_plan).run(seed=3)
    n = st.graph.n
    assert np.array_equal(got.walks[:n], ref.walks)
    print("OK", rep.invalidated_device_shards, "/", rep.device_shards)
""")


@pytest.mark.slow
def test_sharded_update_matches_fresh_rebuild():
    """update() on the sharded backend: only affected shards' device blocks
    respliced, walks bit-identical to a fresh sharded build AND to the
    reference backend at the same store version."""
    r = subprocess.run([sys.executable, "-c", SHARDED_UPDATE_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
