"""Reference walk engine: validity, layout-invariance, sampling correctness."""
import numpy as np
import pytest

from repro.core import rmat
from repro.core.graph import CSRGraph, PaddedGraph
from repro.core.transition import brute_force_probs
from repro.core.walk import WalkParams
from repro.engine import WalkEngine, WalkPlan

PARAMS = WalkParams(p=0.5, q=2.0, length=12)


def simulate_walks(pg, starts, seed, params, walker_ids=None):
    """Reference-backend walks via the engine (the pre-PR 9 shim's shape:
    walker ids default to walker *position*, not start vertex)."""
    starts = np.asarray(starts, np.int32)
    ids = np.arange(len(starts), dtype=np.int32) if walker_ids is None \
        else np.asarray(walker_ids, np.int32)
    eng = WalkEngine.build(pg, WalkPlan.from_params(params))
    return eng.run(starts=starts, seed=seed, walker_ids=ids).walks


def _check_valid(g, walks):
    for i in range(walks.shape[0]):
        prev = i
        for s in range(walks.shape[1]):
            x = int(walks[i, s])
            nb = g.neighbors(prev)
            if len(nb) == 0:
                assert x == prev
            else:
                assert x in nb, (i, s, prev, x)
            prev = x


def test_walks_follow_edges(small_graph):
    pg = PaddedGraph.build(small_graph)
    walks = np.asarray(simulate_walks(pg, np.arange(small_graph.n), 0,
                                      PARAMS))
    assert walks.shape == (small_graph.n, PARAMS.length)
    _check_valid(small_graph, walks)


def test_walks_deterministic(small_graph):
    pg = PaddedGraph.build(small_graph)
    w1 = np.asarray(simulate_walks(pg, np.arange(small_graph.n), 7, PARAMS))
    w2 = np.asarray(simulate_walks(pg, np.arange(small_graph.n), 7, PARAMS))
    w3 = np.asarray(simulate_walks(pg, np.arange(small_graph.n), 8, PARAMS))
    assert np.array_equal(w1, w2)
    assert not np.array_equal(w1, w3)


def test_layout_invariance_base_vs_cache(small_graph):
    """FN-Base and FN-Cache layouts must generate bit-identical walks — the
    paper's claim that all FN variants are exact, strengthened to bit level
    by the deg-width alias construction."""
    g = small_graph
    w_base = np.asarray(simulate_walks(PaddedGraph.build(g),
                                       np.arange(g.n), 0, PARAMS))
    for cap in (8, 16, 24):
        w_cache = np.asarray(simulate_walks(PaddedGraph.build(g, cap=cap),
                                            np.arange(g.n), 0, PARAMS))
        assert np.array_equal(w_base, w_cache), f"cap={cap}"


def test_dead_end_stays():
    g = CSRGraph.from_edges(4, [0], [1])  # vertices 2,3 isolated
    pg = PaddedGraph.build(g)
    walks = np.asarray(simulate_walks(pg, np.arange(4), 0,
                                      WalkParams(length=5)))
    assert np.all(walks[2] == 2) and np.all(walks[3] == 3)


def test_approx_mode_diverges_only_at_hot_vertices(skewed_graph):
    """FN-Approx contract: the first step where an approx walk departs from
    the exact walk must be a step taken *from a popular (hot) vertex* — cold
    transitions are always exact."""
    g = skewed_graph
    cap = 24
    pg = PaddedGraph.build(g, cap=cap)
    exact = np.asarray(simulate_walks(pg, np.arange(g.n), 0, PARAMS))
    approx = np.asarray(simulate_walks(
        pg, np.arange(g.n), 0,
        WalkParams(p=0.5, q=2.0, length=12, mode="approx", approx_eps=5e-2)))
    _check_valid(g, approx)
    deg = g.deg
    n_diverged = 0
    for i in range(g.n):
        diff = np.nonzero(exact[i] != approx[i])[0]
        if len(diff) == 0:
            continue
        n_diverged += 1
        s = diff[0]
        v_at = exact[i, s - 1] if s > 0 else i  # vertex the step left from
        assert deg[v_at] > cap, (i, s, v_at, deg[v_at])
    assert n_diverged > 0  # approximation actually kicked in on this graph


def test_first_step_distribution(small_graph):
    """Step-0 draws follow static edge weights (alias correctness in situ)."""
    g = small_graph
    v = int(np.argmax(g.deg))
    nb, w = g.neighbors(v), g.weights(v)
    pg = PaddedGraph.build(g)
    starts = np.full(6000, v, np.int32)
    walker_ids = np.arange(6000, dtype=np.int32)
    walks = np.asarray(simulate_walks(pg, starts, 0,
                                      WalkParams(length=1),
                                      walker_ids=walker_ids))
    counts = np.array([(walks[:, 0] == x).mean() for x in nb])
    np.testing.assert_allclose(counts, w / w.sum(), atol=0.03)


def test_second_step_distribution():
    """One 2nd-order step matches the brute-force oracle frequencies."""
    g = rmat.wec(6, avg_degree=10, seed=5)
    pg = PaddedGraph.build(g)
    v = int(np.argmax(g.deg))
    p, q = 0.5, 2.0
    starts = np.full(8000, v, np.int32)
    walks = np.asarray(simulate_walks(
        pg, starts, 3, WalkParams(p=p, q=q, length=2),
        walker_ids=np.arange(8000, dtype=np.int32)))
    # group by first step u' (walk v -> u' -> x); compare x frequencies
    first, second = walks[:, 0], walks[:, 1]
    for uprime in np.unique(first)[:3]:
        sel = first == uprime
        if sel.sum() < 500 or g.deg[uprime] == 0:
            continue
        oracle = brute_force_probs(g, v, int(uprime), p, q)
        xs = second[sel]
        for x, pr in oracle.items():
            np.testing.assert_allclose((xs == x).mean(), pr, atol=0.06)


def test_spark_trim_baseline_changes_walks(skewed_graph):
    """The Spark-Node2Vec trim (30 top-weight edges) visibly distorts the
    walk distribution on a skewed graph (paper §2.2 / Fig. 6 setup)."""
    g = skewed_graph
    trimmed = g.trim_top_weights(5)
    pg_t = PaddedGraph.build(trimmed)
    walks = np.asarray(simulate_walks(pg_t, np.arange(g.n), 0, PARAMS))
    counts = trimmed.row_ptr[1:] - trimmed.row_ptr[:-1]
    assert counts.max() <= 5
    # trimmed walks never use edges outside the trimmed graph
    _check_valid(trimmed, walks)
