#!/usr/bin/env bash
# Tiered CI entry point.
# Usage: scripts/ci.sh [tier1|fast|smoke|lint|serve-smoke|train-smoke|
#                       train-shard-smoke|update-smoke]
#   tier1 (default) — the full suite, the bar every PR must hold.
#                     Runtime varies 8 min - 2.5 h with machine load, so it
#                     runs nightly / on demand, NOT per push.
#   fast            — deselect `slow` (distributed/subprocess/bench-shaped)
#   smoke           — the per-push gate: forbidden-API lint, import check,
#                     collect-only, then a fast unit subset (minutes)
#   lint            — just the forbidden-API checks (jax-0.4.37 quirks)
#   serve-smoke     — serving end-to-end: serve_graph --smoke replays a Zipf
#                     trace, then bench_serve --smoke gates the serve_*
#                     ratios against the committed baseline
#   train-smoke     — streamed walk→SGNS training end-to-end: the train
#                     parity battery, then bench_train --smoke gates the
#                     train_* ratios against the committed baseline
#   train-shard-smoke — sharded SGNS end-to-end: the shard parity battery
#                     (incl. the 2-fake-device subprocess bit-identity
#                     tests), then bench_train --smoke re-gates the
#                     train_* ratios — including the ISSUE-10 acceptance
#                     asserts (bit-identical across shard counts, shard2/
#                     dense pairs/sec >= 1.5x) that run inside the bench
#   update-smoke    — incremental graph updates end-to-end: the delta /
#                     engine.update parity batteries, then bench_update
#                     --smoke gates the update_* ratios (and the ISSUE-9
#                     acceptance asserts) against the committed baseline
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Fast unit subset for the smoke tier: core graph/ingest/sampling math.
# Everything here runs in seconds; the heavyweight LM-lowering and
# multi-device subprocess suites stay in tier-1.
SMOKE_TESTS=(tests/test_graph.py tests/test_ingest.py tests/test_alias.py
             tests/test_transition.py)

lint() {
  # Forbidden APIs — environment quirks codified so they can't regress
  # (jax 0.4.37: no jax.shard_map; cost_analysis() returns a list; the
  # container has no hypothesis and pip install is not permitted).
  local fail=0
  local paths=(src tests benchmarks examples scripts)

  if grep -rnE "^[[:space:]]*(import hypothesis|from hypothesis)" \
       "${paths[@]}" --include="*.py"; then
    echo "LINT FAIL: hypothesis is not installed in the CI container;" \
         "use seeded pytest.mark.parametrize sweeps instead" >&2
    fail=1
  fi

  # bare jax.shard_map does not exist on jax 0.4.37 — everything must go
  # through the _shard_map compat shim in core/walk_distributed.py
  if grep -rn "jax\.shard_map" "${paths[@]}" --include="*.py" \
       | grep -v "src/repro/core/walk_distributed.py"; then
    echo "LINT FAIL: bare jax.shard_map (absent on jax 0.4.37); use the" \
         "_shard_map shim in repro.core.walk_distributed" >&2
    fail=1
  fi

  # compiled.cost_analysis() returns a list on jax 0.4.37 — direct
  # indexing belongs only in the roofline normalizer (cost_dict)
  if grep -rn "\.cost_analysis()\[" "${paths[@]}" --include="*.py" \
       | grep -v "src/repro/roofline/analysis.py"; then
    echo "LINT FAIL: direct cost_analysis()[...] indexing (list on jax" \
         "0.4.37); normalize via repro.roofline.analysis.cost_dict" >&2
    fail=1
  fi

  # the streamed trainer's contract is "no host round-trips in the hot
  # path" (DESIGN.md §14/§16): device syncs in src/repro/train/ must be
  # per-round or terminal, and say so with a `# host-ok: ...` tag on the
  # line. block_until_ready has no legitimate use there at all.
  if grep -rn "\.block_until_ready()" src/repro/train/ --include="*.py"; then
    echo "LINT FAIL: block_until_ready in the streamed trainer (host" \
         "sync in the hot path); let dispatch run ahead instead" >&2
    fail=1
  fi
  if grep -rnE "\bnp\.asarray|\bnp\.ascontiguousarray|jax\.device_get" \
       src/repro/train/ --include="*.py" | grep -v "# host-ok"; then
    echo "LINT FAIL: host round-trip in src/repro/train/ without a" \
         "'# host-ok: <why>' tag (only per-round input staging and" \
         "terminal fetches are allowed in the streamed trainer)" >&2
    fail=1
  fi

  if [ "$fail" -ne 0 ]; then exit 1; fi
  echo "lint: forbidden-API checks passed"
}

target="${1:-tier1}"
case "$target" in
  tier1) exec python -m pytest -x -q --durations=10 ;;
  fast)  exec python -m pytest -x -q -m "not slow" --durations=10 ;;
  lint)  lint ;;
  smoke)
    lint
    echo "smoke: import check"
    python -c "import repro.engine, repro.data, repro.data.ingest, \
repro.data.deltas, repro.core.graph, repro.core.walk_distributed, \
repro.roofline.analysis, repro.serve, repro.train; print('imports OK')"
    echo "smoke: collect-only"
    python -m pytest -q --collect-only >/dev/null
    echo "smoke: fast unit subset"
    exec python -m pytest -x -q -m "not slow" --durations=10 \
      "${SMOKE_TESTS[@]}"
    ;;
  serve-smoke)
    echo "serve-smoke: end-to-end Zipf trace through the embedding service"
    python -m repro.launch.serve_graph --smoke
    echo "serve-smoke: deterministic serve_* ratios vs baseline"
    python -m benchmarks.bench_serve --smoke BENCH_smoke.json
    exec python scripts/bench_compare.py BENCH_smoke.json \
      benchmarks/baselines/BENCH_smoke.json --strict --only serve_
    ;;
  train-smoke)
    echo "train-smoke: streamed-vs-concat / fused-vs-jnp parity battery"
    python -m pytest -x -q tests/test_train.py
    echo "train-smoke: train_* ratios vs baseline"
    python -m benchmarks.bench_train --smoke BENCH_smoke.json
    exec python scripts/bench_compare.py BENCH_smoke.json \
      benchmarks/baselines/BENCH_smoke.json --strict --only train_
    ;;
  train-shard-smoke)
    lint
    echo "train-shard-smoke: sharded parity battery (2-device subprocess" \
         "bit-identity, numpy oracle, zero-retrace, alias parity)"
    python -m pytest -x -q tests/test_train_shard.py
    echo "train-shard-smoke: train_* ratios vs baseline (incl. the" \
         "shard2/dense >= 1.5x and bit-identity asserts in the bench)"
    python -m benchmarks.bench_train --smoke BENCH_smoke.json
    exec python scripts/bench_compare.py BENCH_smoke.json \
      benchmarks/baselines/BENCH_smoke.json --strict --only train_shard_
    ;;
  update-smoke)
    echo "update-smoke: delta ingestion + engine.update parity batteries"
    python -m pytest -x -q -m "not slow" tests/test_deltas.py \
      tests/test_update.py
    echo "update-smoke: update_* ratios vs baseline"
    python -m benchmarks.bench_update --smoke BENCH_smoke.json
    exec python scripts/bench_compare.py BENCH_smoke.json \
      benchmarks/baselines/BENCH_smoke.json --strict --only update_
    ;;
  *) echo "unknown target: $target" \
          "(want tier1|fast|smoke|lint|serve-smoke|train-smoke|" \
          "train-shard-smoke|update-smoke)" >&2
     exit 2 ;;
esac
