#!/usr/bin/env bash
# Tier-1 CI entry point. Usage: scripts/ci.sh [tier1|fast]
#   tier1 (default) — the full suite, the bar every PR must hold
#   fast            — deselect `slow` (distributed/subprocess/bench-shaped)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

target="${1:-tier1}"
case "$target" in
  tier1) exec python -m pytest -x -q ;;
  fast)  exec python -m pytest -x -q -m "not slow" ;;
  *) echo "unknown target: $target (want tier1|fast)" >&2; exit 2 ;;
esac
