#!/usr/bin/env python
"""Compare a BENCH_smoke.json against the committed baseline.

Only the ``metrics`` block is compared — these are ratio / deterministic
quantities by construction (benchmarks/bench_smoke.py); absolute wall-clock
lives in ``info`` and is ignored because it varies 2-5x with machine load.

A metric "regresses" when it drifts by more than ``--threshold`` (default
2.0) in either direction: drift = max(new/old, old/new). Default behavior
is warn-and-exit-0 (the nightly job stays green but prints WARN lines);
``--strict`` turns warnings into a non-zero exit for gating.

    python scripts/bench_compare.py BENCH_smoke.json \
        benchmarks/baselines/BENCH_smoke.json [--threshold 2.0] [--strict]
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(new: dict, base: dict, threshold: float,
            only: str = "") -> list[str]:
    warnings = []
    new_m = new.get("metrics", {})
    base_m = base.get("metrics", {})
    if only:
        new_m = {k: v for k, v in new_m.items() if k.startswith(only)}
        base_m = {k: v for k, v in base_m.items() if k.startswith(only)}
    for key in sorted(base_m):
        old = base_m[key]
        if key not in new_m:
            warnings.append(f"WARN {key}: missing from new run")
            continue
        cur = new_m[key]
        if old == 0 or cur == 0:
            drift = float("inf") if cur != old else 1.0
        else:
            r = cur / old
            drift = max(r, 1.0 / r)
        line = f"{key}: baseline={old:.4g} new={cur:.4g} drift={drift:.2f}x"
        if drift > threshold:
            warnings.append(f"WARN {line} (> {threshold}x)")
            print(f"WARN {line}  <-- regression", flush=True)
        else:
            print(f"  ok {line}", flush=True)
    for key in sorted(set(new_m) - set(base_m)):
        print(f" new {key}: {new_m[key]:.4g} (no baseline yet)", flush=True)
    return warnings


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly emitted BENCH_smoke.json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="max allowed drift ratio in either direction")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any regression (default: warn only)")
    ap.add_argument("--only", default="",
                    help="compare only metrics whose name starts with this "
                         "prefix (e.g. serve_) — lets a partial emitter "
                         "gate its own keys without WARNing on the rest")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    warnings = compare(new, base, args.threshold, only=args.only)
    if warnings:
        print(f"{len(warnings)} metric(s) drifted > {args.threshold}x",
              file=sys.stderr)
        return 1 if args.strict else 0
    print("all metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
