"""Paper Fig. 10/11 — WeC-K graphs (WeChat-like, skewed, avg degree ~100
scaled down): FN-Cache and FN-Approx improvements + linear scaling in K.
All engines run through the unified WalkEngine API."""
from __future__ import annotations

from benchmarks.common import row, time_fn
from benchmarks import common
from repro.engine import WalkEngine, WalkPlan


def run():
    cap = 32
    for k in (9, 10, 11):
        g = common.graph(f"wec:k={k},deg=40,seed=0")
        base = dict(p=2.0, q=0.5, length=30)
        engines = {
            "fn_base": WalkEngine.build(g, WalkPlan(**base)),
            "fn_cache": WalkEngine.build(g, WalkPlan(cap=cap, **base)),
            "fn_approx": WalkEngine.build(
                g, WalkPlan(cap=cap, mode="approx", approx_eps=5e-2, **base)),
        }
        us = {name: time_fn(lambda e=e: e.run(seed=0).walks)
              for name, e in engines.items()}
        row(f"wec{k}_fn_base", us["fn_base"],
            f"us_per_vertex={us['fn_base'] / g.n:.2f}")
        row(f"wec{k}_fn_cache", us["fn_cache"],
            f"speedup={us['fn_base'] / us['fn_cache']:.2f}x")
        row(f"wec{k}_fn_approx", us["fn_approx"],
            f"speedup={us['fn_base'] / us['fn_approx']:.2f}x")


if __name__ == "__main__":
    run()
