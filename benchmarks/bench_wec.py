"""Paper Fig. 10/11 — WeC-K graphs (WeChat-like, skewed, avg degree ~100
scaled down): FN-Cache and FN-Approx improvements + linear scaling in K."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_fn
from repro.core import rmat
from repro.core.graph import PaddedGraph
from repro.core.walk import WalkParams, simulate_walks


def run():
    cap = 32
    for k in (9, 10, 11):
        g = rmat.wec(k, avg_degree=40, seed=0)
        starts = np.arange(g.n)
        wp = WalkParams(p=2.0, q=0.5, length=30)
        pg_base = PaddedGraph.build(g)
        pg_cache = PaddedGraph.build(g, cap=cap)
        us_base = time_fn(lambda: simulate_walks(pg_base, starts, 0, wp))
        us_cache = time_fn(lambda: simulate_walks(pg_cache, starts, 0, wp))
        us_approx = time_fn(lambda: simulate_walks(
            pg_cache, starts, 0,
            WalkParams(p=2.0, q=0.5, length=30, mode="approx",
                       approx_eps=5e-2)))
        row(f"wec{k}_fn_base", us_base, f"us_per_vertex={us_base / g.n:.2f}")
        row(f"wec{k}_fn_cache", us_cache,
            f"speedup={us_base / us_cache:.2f}x")
        row(f"wec{k}_fn_approx", us_approx,
            f"speedup={us_base / us_approx:.2f}x")


if __name__ == "__main__":
    run()
