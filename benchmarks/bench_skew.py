"""Paper Fig. 12/13/14 + Fig. 5 — Skew-S analysis: as degree skew grows,
(1) walks concentrate on popular vertices (Fig. 5), (2) FN-Base slows down,
(3) FN-Cache / FN-Approx win more (Fig. 13), (4) hot-message volume grows
(Fig. 14 — here: the exact bytes FN-Cache keeps off the wire).
All engines run through the unified WalkEngine API."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_fn
from benchmarks import common
from repro.engine import WalkEngine, WalkPlan


def run():
    cap = 32
    for s in (1, 2, 3, 4, 5):
        g = common.graph(f"skew:s={s},k=10,deg=30,seed=0")
        base = dict(p=0.5, q=2.0, length=30)
        eng_base = WalkEngine.build(g, WalkPlan(**base))
        eng_cache = WalkEngine.build(g, WalkPlan(cap=cap, **base))
        eng_approx = WalkEngine.build(
            g, WalkPlan(cap=cap, mode="approx", approx_eps=5e-2, **base))
        us_base = time_fn(lambda: eng_base.run(seed=0).walks)
        us_cache = time_fn(lambda: eng_cache.run(seed=0).walks)
        us_approx = time_fn(lambda: eng_approx.run(seed=0).walks)
        walks = eng_base.run(seed=0).walks
        visits = np.bincount(walks.reshape(-1), minlength=g.n)
        deg = g.deg.astype(np.float64)
        corr = float(np.corrcoef(deg, visits[:g.n])[0, 1])
        hot = deg > cap
        hot_visit_share = visits[:g.n][hot].sum() / visits.sum()
        # NEIG bytes a push-based engine would move for hot vertices per
        # superstep (what FN-Cache keeps off the wire): visits x deg x 8B
        hot_neig_bytes = int((visits[:g.n][hot] * deg[hot]).sum() * 8
                             / eng_base.plan.length)
        row(f"skew{s}_fn_base", us_base,
            f"deg_visit_corr={corr:.2f};hot_visit_share={hot_visit_share:.2f}")
        row(f"skew{s}_fn_cache", us_cache,
            f"speedup={us_base / us_cache:.2f}x;"
            f"hot_neig_bytes_per_superstep={hot_neig_bytes}")
        row(f"skew{s}_fn_approx", us_approx,
            f"speedup={us_base / us_approx:.2f}x")


if __name__ == "__main__":
    run()
