"""Paper Fig. 12/13/14 + Fig. 5 — Skew-S analysis: as degree skew grows,
(1) walks concentrate on popular vertices (Fig. 5), (2) FN-Base slows down,
(3) FN-Cache / FN-Approx win more (Fig. 13), (4) hot-message volume grows
(Fig. 14 — here: the exact bytes FN-Cache keeps off the wire)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_fn
from repro.core import rmat
from repro.core.graph import PaddedGraph
from repro.core.walk import WalkParams, simulate_walks


def run():
    cap = 32
    for s in (1, 2, 3, 4, 5):
        g = rmat.skew(s, k=10, avg_degree=30, seed=0)
        starts = np.arange(g.n)
        wp = WalkParams(p=0.5, q=2.0, length=30)
        pg_base = PaddedGraph.build(g)
        pg_cache = PaddedGraph.build(g, cap=cap)
        us_base = time_fn(lambda: simulate_walks(pg_base, starts, 0, wp))
        us_cache = time_fn(lambda: simulate_walks(pg_cache, starts, 0, wp))
        us_approx = time_fn(lambda: simulate_walks(
            pg_cache, starts, 0,
            WalkParams(p=0.5, q=2.0, length=30, mode="approx",
                       approx_eps=5e-2)))
        walks = np.asarray(simulate_walks(pg_base, starts, 0, wp))
        visits = np.bincount(walks.reshape(-1), minlength=g.n)
        deg = g.deg.astype(np.float64)
        corr = float(np.corrcoef(deg, visits[:g.n])[0, 1])
        hot = deg > cap
        hot_visit_share = visits[:g.n][hot].sum() / visits.sum()
        # NEIG bytes a push-based engine would move for hot vertices per
        # superstep (what FN-Cache keeps off the wire): visits x deg x 8B
        hot_neig_bytes = int((visits[:g.n][hot] * deg[hot]).sum() * 8
                             / wp.length)
        row(f"skew{s}_fn_base", us_base,
            f"deg_visit_corr={corr:.2f};hot_visit_share={hot_visit_share:.2f}")
        row(f"skew{s}_fn_cache", us_cache,
            f"speedup={us_base / us_cache:.2f}x;"
            f"hot_neig_bytes_per_superstep={hot_neig_bytes}")
        row(f"skew{s}_fn_approx", us_approx,
            f"speedup={us_base / us_approx:.2f}x")


if __name__ == "__main__":
    run()
