"""Serving-layer benchmark: synthetic Zipf traffic through EmbeddingService.

Battery mode (``run()``, wired into ``benchmarks.run``) prints the usual
``name,us_per_call,derived`` CSV rows: per-request service time across Zipf
exponents (hotter traffic -> higher hit rate -> faster), plus the
batched-vs-single-request gather ratio.

Smoke mode (``--smoke [out.json]``) emits **ratio / deterministic** metrics
into the ``BENCH_smoke.json`` schema (merging with an existing file so the
walk metrics survive), gated by ``scripts/bench_compare.py --strict``:

* ``serve_hit_rate_zipf``       — cache hit rate of a fixed virtual-clock
                                  Zipf replay (policy-deterministic: same
                                  trace + same admission = same number).
* ``serve_occupancy_zipf``      — mean batch occupancy of that replay
                                  (deterministic for the same reason).
* ``serve_expired_share_starved`` — share of requests shed when the queue
                                  is starved past every deadline
                                  (deterministic).
* ``serve_compile_shapes_per_bucket`` — distinct jit shapes / available
                                  buckets after the replay; > its baseline
                                  means a per-request-recompile regression.
* ``serve_batched_over_single_us`` — wall-time ratio of one 128-wide batched
                                  gather vs 128 single gathers (interleaved
                                  timing; machine load cancels).
"""
from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks.common import graph, row, time_fn
from repro.engine import WalkPlan
from repro.serve import EmbeddingService, VirtualClock, synthetic_trace

SPEC = "skew:s=4,k=9,deg=20,seed=3,relabel=degree"
CAP = 24
DIM = 64
REQUESTS = 2000
K = 8


def _embeddings(n: int, dim: int = DIM, seed: int = 0) -> np.ndarray:
    """Deterministic stand-in SGNS table (the bench measures serving, not
    embedding quality; unit rows keep dot products bounded)."""
    emb = np.random.default_rng(seed).normal(size=(n, dim)).astype(np.float32)
    return emb / np.linalg.norm(emb, axis=1, keepdims=True)


def _service(g, clock=None, cache_size: int = 256) -> EmbeddingService:
    return EmbeddingService(
        g, _embeddings(g.n), plan=WalkPlan(backend="reference", cap=CAP),
        cache_size=cache_size, linger_s=1e-4, margin_s=1e-4,
        **({"clock": clock} if clock is not None else {}))


def _replay(svc: EmbeddingService, clock: VirtualClock, alpha: float,
            num: int = REQUESTS, deadline_s: float = 0.05) -> int:
    trace = synthetic_trace(svc.graph.n, num, alpha=alpha, qps=10_000.0,
                            deadline_s=deadline_s, seed=0)
    lost = 0
    for ev in trace:
        clock.t = ev.t_arrival
        svc.submit(ev.kind, ev.node, k=K, deadline_s=ev.deadline_s,
                   now=clock())
        svc.pump(now=clock())
    svc.drain(now=clock() + 1.0)
    st = svc.stats()
    lost = num - st.requests - st.expired
    assert lost == 0, f"lost {lost} responses"
    return st.requests


def run() -> None:
    g = graph(SPEC)
    for alpha in (0.8, 1.1, 1.4):
        clock = VirtualClock()
        svc = _service(g, clock=clock)
        import time as _time
        t0 = _time.perf_counter()
        _replay(svc, clock, alpha)
        us = (_time.perf_counter() - t0) / REQUESTS * 1e6
        st = svc.stats()
        row(f"serve_zipf{alpha:g}", us,
            f"hit_rate={st.cache_hit_rate:.3f};"
            f"occupancy={st.batch_occupancy:.3f};expired={st.expired}")

    svc = _service(g)
    nodes = np.arange(128, dtype=np.int32)
    us_batch = time_fn(lambda: svc.embed(nodes), warmup=1, iters=5)
    us_single = time_fn(
        lambda: [svc.embed(int(v)) for v in nodes[:16]], warmup=1, iters=5)
    us_single *= 128 / 16          # per-128 equivalent
    row("serve_embed_batch128", us_batch,
        f"single_equiv_us={us_single:.0f};"
        f"batch_speedup={us_single / us_batch:.1f}x")


def smoke_metrics(info: dict) -> dict:
    """The ratio metrics described in the module docstring."""
    g = graph(SPEC)

    clock = VirtualClock()
    svc = _service(g, clock=clock)
    _replay(svc, clock, alpha=1.2)
    st = svc.stats()
    buckets = len(svc.batcher.buckets)
    groups = {s[0] for s in svc.compiled_shapes}
    info["serve_requests"] = st.requests
    info["serve_batches"] = st.batches
    metrics = {
        "serve_hit_rate_zipf": st.cache_hit_rate,
        "serve_occupancy_zipf": st.batch_occupancy,
        "serve_compile_shapes_per_bucket":
            len(svc.compiled_shapes) / (buckets * max(len(groups), 1)),
    }

    # starved queue: after a warm pass fills the cache, stall the pump until
    # every deadline is long gone — hits were answered at submit and
    # survive; everything that had to queue is shed. The resulting share is
    # a deterministic joint property of the admission policy and the shed
    # path (1.0 would mean the cache stopped answering, 0.0 that expiry
    # stopped shedding).
    from repro.serve import StatsRecorder
    clock = VirtualClock()
    svc = _service(g, clock=clock)
    _replay(svc, clock, alpha=1.2, num=512)          # warm the cache
    svc.recorder = StatsRecorder()                   # fresh stats window
    trace = synthetic_trace(g.n, 256, alpha=1.2, qps=10_000.0,
                            deadline_s=1e-3, seed=1)
    t0 = clock.t
    for ev in trace:
        clock.t = t0 + ev.t_arrival
        svc.submit(ev.kind, ev.node, k=K, deadline_s=ev.deadline_s,
                   now=clock())
    clock.advance(10.0)
    svc.drain(now=clock())
    st = svc.stats()
    metrics["serve_expired_share_starved"] = st.expired / 256

    svc = _service(g)
    nodes = np.arange(128, dtype=np.int32)
    us_batch = time_fn(lambda: svc.embed(nodes), warmup=1, iters=5)
    us_single = time_fn(
        lambda: [svc.embed(int(v)) for v in nodes[:16]], warmup=1, iters=5
    ) * (128 / 16)
    info["serve_embed_batch128_us"] = us_batch
    info["serve_embed_single128_equiv_us"] = us_single
    metrics["serve_batched_over_single_us"] = us_batch / us_single
    return metrics


def run_smoke(out_path: str = "BENCH_smoke.json") -> dict:
    """Merge serve metrics into ``out_path`` (existing walk metrics, if the
    file is already there, are preserved)."""
    try:
        with open(out_path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        doc = {"version": 1, "metrics": {}, "info": {}}
    info = doc.setdefault("info", {})
    metrics = smoke_metrics(info)
    doc.setdefault("metrics", {}).update(metrics)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    for k in sorted(metrics):
        print(f"{k} = {metrics[k]:.4g}")
    print(f"wrote {out_path}")
    return doc


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--smoke"]
        run_smoke(args[0] if args else "BENCH_smoke.json")
    else:
        run()
