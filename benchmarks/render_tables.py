"""Render EXPERIMENTS.md markdown tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.render_tables [--tag opt]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
ARTW = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "dryrun_walk")


def render(tag: str = "") -> str:
    lines = ["| arch | shape | mesh | t_compute | t_memory | t_collective |"
             " dominant | useful | fraction | mem/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        t = parts[3] if len(parts) > 3 else ""
        if t != tag:
            continue
        a = json.load(open(path))
        if a.get("status") == "skipped":
            lines.append(f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
                         f"— skipped: {a['reason'][:58]} | | | | | | |")
            continue
        mem = (a.get("memory") or {}).get("resident_bytes") or 0
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute']:.3e} | {a['t_memory']:.3e} "
            f"| {a['t_collective']:.3e} | {a['bottleneck']} "
            f"| {a['useful_ratio']:.2f} | {a['roofline_fraction']:.4f} "
            f"| {mem/2**30:.2f} GiB |")
    return "\n".join(lines)


def render_walk() -> str:
    lines = ["| cell | cap | mode | capacity | flops/step/dev | "
             "coll bytes/step/dev | t_compute | t_collective |",
             "|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(ARTW, "*.json"))):
        a = json.load(open(path))
        lines.append(
            f"| {a['cell']} | {a['cap']} | {a['mode']} | {a['capacity']} "
            f"| {a['flops_per_step_per_dev']:.2e} "
            f"| {a['coll_bytes_per_step_per_dev']/2**20:.1f} MiB "
            f"| {a['t_compute']:.2e} | {a['t_collective']:.2e} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--walk", action="store_true")
    args = ap.parse_args()
    print(render_walk() if args.walk else render(args.tag))
