"""Shared benchmark helpers. All benches print ``name,us_per_call,derived``
CSV rows (one per configuration) so ``benchmarks.run`` stays parseable."""
from __future__ import annotations

import time
from typing import Callable

import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
    _block(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def _block(out):
    import jax
    jax.block_until_ready(out)


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


def graph(spec: str, cache_dir: str = None):
    """Resolve a dataset spec (``repro.data.ingest``). Set the
    ``BENCH_GRAPH_CACHE`` env var to memmap-cache on-disk edge lists
    across bench runs (EXPERIMENTS.md §Datasets)."""
    import os

    from repro.data import open_graph
    cache = cache_dir or os.environ.get("BENCH_GRAPH_CACHE")
    return open_graph(spec, cache_dir=cache).graph


def dataset(spec: str):
    from repro.data.ingest import _load_dataset
    return _load_dataset(spec)
