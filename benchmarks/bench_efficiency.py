"""Paper Fig. 7/8 — walk-stage efficiency across engines on real-graph-like
inputs (CPU-scaled WeC graphs). Spark-Node2Vec is emulated faithfully to its
two costs: (i) full 2nd-order transition-probability PRE-COMPUTATION over all
(u,v) pairs (the paper's Eq. 1 memory/time sink) and (ii) per-step joins —
modeled here by the same walk engine but paying the precompute every run.
Derived: speedup over the spark emulation (paper: 7.7-122x)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, time_fn
from benchmarks import common
from repro.core.graph import PaddedGraph
from repro.core.transition import unnormalized_probs
from repro.engine import WalkEngine, WalkPlan


def _spark_emulation_precompute(g, p, q):
    """Pre-compute alias tables for every (prev, cur) edge pair — what
    Spark-Node2Vec does before walking (on the trimmed graph)."""
    import jax
    import jax.numpy as jnp
    pg = PaddedGraph.build(g)
    t0 = time.perf_counter()
    # vectorized over all directed edges (u -> v): probs over N(v)
    us, vs = [], []
    for v in range(g.n):
        for u in g.neighbors(v):
            us.append(u)
            vs.append(v)
    us = jnp.asarray(np.asarray(us, np.int32))
    vs = jnp.asarray(np.asarray(vs, np.int32))

    @jax.jit
    def all_pair_probs(us, vs):
        return jax.vmap(lambda u, v: unnormalized_probs(
            pg.adj[v], pg.wgt[v], u, pg.adj[u], p, q))(us, vs)

    probs = all_pair_probs(us, vs)
    probs.block_until_ready()
    return time.perf_counter() - t0, probs.size * 8  # 8B alias entry


def run():
    p, q = 0.5, 2.0
    for k, avg in [(9, 20), (10, 30)]:
        g = common.graph(f"wec:k={k},deg={avg},seed=0")
        length = 40

        # spark emulation: trim + full pair precompute + walk
        trimmed = g.trim_top_weights(8)
        t_pre, pre_bytes = _spark_emulation_precompute(trimmed, p, q)
        eng_t = WalkEngine.build(trimmed, WalkPlan(p=p, q=q, length=length))
        us_walk = time_fn(lambda: eng_t.run(seed=0).walks)
        spark_total = t_pre * 1e6 + us_walk
        row(f"efficiency_spark_sim_k{k}", spark_total,
            f"precompute_bytes={pre_bytes}")

        engines = {
            "fn_base": WalkEngine.build(
                g, WalkPlan(p=p, q=q, length=length)),
            "fn_cache": WalkEngine.build(
                g, WalkPlan(p=p, q=q, length=length, cap=24)),
            "fn_approx": WalkEngine.build(
                g, WalkPlan(p=p, q=q, length=length, cap=24, mode="approx",
                            approx_eps=5e-2)),
        }
        for name, eng in engines.items():
            us = time_fn(lambda eng=eng: eng.run(seed=0).walks)
            row(f"efficiency_{name}_k{k}", us,
                f"speedup_vs_spark={spark_total / us:.1f}x")


if __name__ == "__main__":
    run()
