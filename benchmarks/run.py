"""Benchmark harness — one module per paper table/figure (see DESIGN.md §9).
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_accuracy, bench_breakdown,
                            bench_efficiency, bench_growth, bench_memory,
                            bench_scaling, bench_serve, bench_skew,
                            bench_train, bench_update, bench_wec,
                            roofline_table)
    print("name,us_per_call,derived")
    suites = [
        ("breakdown (Fig.1)", bench_breakdown),
        ("memory (Eq.1)", bench_memory),
        ("message growth (Fig.4/5)", bench_growth),
        ("efficiency (Fig.7/8)", bench_efficiency),
        ("scaling ER-K (Fig.9)", bench_scaling),
        ("WeC-K (Fig.10/11)", bench_wec),
        ("Skew-S (Fig.5/12/13/14)", bench_skew),
        ("accuracy (Fig.6)", bench_accuracy),
        ("serving (DESIGN §13)", bench_serve),
        ("training (DESIGN §14)", bench_train),
        ("incremental updates (DESIGN §15)", bench_update),
        ("roofline table (dry-run)", roofline_table),
    ]
    failed = []
    for name, mod in suites:
        print(f"# --- {name} ---", flush=True)
        try:
            mod.run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
