"""Paper Fig. 9 — scalability on ER-K graphs (uniform degree ~10): walk time
should scale linearly in the number of vertices. CPU-scaled K; derived:
time per vertex (flat => linear scaling, the paper's finding)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_fn
from repro.core import rmat
from repro.core.graph import PaddedGraph
from repro.core.walk import WalkParams, simulate_walks


def run():
    per_vertex = []
    for k in (10, 11, 12, 13):
        g = rmat.er(k, avg_degree=10, seed=0)
        pg = PaddedGraph.build(g)
        starts = np.arange(g.n)
        us = time_fn(lambda: simulate_walks(
            pg, starts, 0, WalkParams(p=0.5, q=2.0, length=40)))
        per_vertex.append(us / g.n)
        row(f"scaling_er{k}", us, f"us_per_vertex={us / g.n:.2f}")
    lin = max(per_vertex) / max(min(per_vertex), 1e-9)
    row("scaling_linearity", 0.0,
        f"max_over_min_us_per_vertex={lin:.2f} (1.0 = perfectly linear)")


if __name__ == "__main__":
    run()
