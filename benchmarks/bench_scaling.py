"""Paper Fig. 9 — scalability on ER-K graphs (uniform degree ~10): walk time
should scale linearly in the number of vertices. CPU-scaled K; derived:
time per vertex (flat => linear scaling, the paper's finding)."""
from __future__ import annotations

from benchmarks.common import row, time_fn
from benchmarks import common
from repro.engine import WalkEngine, WalkPlan


def run():
    per_vertex = []
    for k in (10, 11, 12, 13):
        g = common.graph(f"er:k={k},deg=10,seed=0")
        eng = WalkEngine.build(g, WalkPlan(p=0.5, q=2.0, length=40))
        us = time_fn(lambda: eng.run(seed=0).walks)
        per_vertex.append(us / g.n)
        row(f"scaling_er{k}", us, f"us_per_vertex={us / g.n:.2f}")
    lin = max(per_vertex) / max(min(per_vertex), 1e-9)
    row("scaling_linearity", 0.0,
        f"max_over_min_us_per_vertex={lin:.2f} (1.0 = perfectly linear)")


if __name__ == "__main__":
    run()
