"""Paper Fig. 6 — node classification accuracy: exact Fast-Node2Vec vs
FN-Approx vs the Spark trim-30 baseline.

BlogCatalog is not available offline; a labeled SBM graph reproduces the
qualitative claim: the trim baseline destroys accuracy while FN-Approx
matches FN-Exact. Derived column: micro-F1 / macro-F1 on a 50% split.

``accuracy_budget_r{R}`` rows sweep the walk budget (num_walks rounds at a
fixed length): how much corpus the downstream task actually needs, i.e.
where the F1-vs-walk-budget curve flattens. Recorded in EXPERIMENTS.md
§Accuracy — the knee is what sizes the streamed trainer's round count."""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, row, time_fn
from repro.core.node2vec import Node2VecConfig, generate_walks, \
    train_embeddings


def _f1(emb, labels, seed=0):
    rng = np.random.default_rng(seed)
    n = emb.shape[0]
    k = labels.max() + 1
    idx = rng.permutation(n)
    tr, te = idx[:n // 2], idx[n // 2:]
    y = np.eye(k)[labels]
    w, *_ = np.linalg.lstsq(emb[tr], y[tr], rcond=None)
    pred = (emb[te] @ w).argmax(1)
    gold = labels[te]
    micro = (pred == gold).mean()
    f1s = []
    for c in range(k):
        tp = ((pred == c) & (gold == c)).sum()
        fp = ((pred == c) & (gold != c)).sum()
        fn = ((pred != c) & (gold == c)).sum()
        p = tp / max(tp + fp, 1)
        r = tp / max(tp + fn, 1)
        f1s.append(2 * p * r / max(p + r, 1e-9))
    return micro, float(np.mean(f1s))


def run():
    # SBM with weighted edges so trim-by-weight actually bites
    ds = dataset("sbm:n=400,c=4,pin=0.06,pout=0.004,seed=1")
    g, labels = ds.graph, ds.labels
    rng = np.random.default_rng(0)
    g.wgt = (rng.random(g.m) * 4 + 0.5).astype(np.float32)

    base = dict(p=1.0, q=0.5, walk_length=20, num_walks=4, window=5, dim=32,
                epochs=2, batch_size=4096, seed=0)
    variants = {
        "fn_exact": Node2VecConfig(mode="exact", **base),
        "fn_approx": Node2VecConfig(mode="approx", approx_eps=5e-2,
                                    cap=16, **base),
        # beyond-paper static-shape-native approximation (EXPERIMENTS §Perf)
        "fn_approx_always": Node2VecConfig(mode="approx_always", cap=16,
                                           **base),
    }
    for name, cfg in variants.items():
        walks = generate_walks(g, cfg)
        emb = train_embeddings(g, walks, cfg)
        micro, macro = _f1(emb, labels)
        row(f"accuracy_{name}", 0.0, f"micro_f1={micro:.3f};"
                                     f"macro_f1={macro:.3f}")
    # spark-trim30 baseline (here trim-4 to match the smaller degree scale:
    # paper keeps 30 of ~100s-1000s of edges; we keep ~similar fraction)
    trimmed = g.trim_top_weights(4)
    cfg = Node2VecConfig(mode="exact", **base)
    walks = generate_walks(trimmed, cfg)
    emb = train_embeddings(trimmed, walks, cfg)
    micro, macro = _f1(emb, labels)
    row("accuracy_spark_trim", 0.0, f"micro_f1={micro:.3f};"
                                    f"macro_f1={macro:.3f}")

    # F1 vs walk budget: same graph/config, num_walks swept. One full-budget
    # corpus is generated once per budget (not prefix-sliced) so each point
    # is exactly what a run configured with that budget would produce.
    for budget in (1, 2, 4, 8):
        cfg = Node2VecConfig(mode="exact", **{**base, "num_walks": budget})
        walks = generate_walks(g, cfg)
        emb = train_embeddings(g, walks, cfg)
        micro, macro = _f1(emb, labels)
        row(f"accuracy_budget_r{budget}", 0.0,
            f"walks={walks.shape[0]};micro_f1={micro:.3f};"
            f"macro_f1={macro:.3f}")


if __name__ == "__main__":
    run()
