"""Smoke benchmark — minutes, machine-readable, regression-comparable.

Emits ``BENCH_smoke.json`` with **ratio** metrics only: wall-clock on this
container varies 2-5x with machine load (EXPERIMENTS.md §Methodology), so
the nightly gate compares ratios of interleaved runs (load cancels) and
deterministic layout/allocation quantities (exact), never absolute time.
Raw microseconds are recorded under ``info`` for humans but are not
compared by ``scripts/bench_compare.py``.

    make bench-smoke            # emit + compare against committed baseline
    PYTHONPATH=src python -m benchmarks.bench_smoke [out.json]
"""
from __future__ import annotations

import json
import sys
import tracemalloc

import numpy as np

from benchmarks.common import graph, time_fn
from repro.core.graph import CSRGraph
from repro.data.ingest import csr_from_chunks
from repro.engine import WalkEngine, WalkPlan
from repro.roofline.traffic import walk_collective_bytes, walk_overlap_model

SKEW_SPEC = "skew:s=4,k=9,deg=20,seed=3"
CAP = 24


def _peak(fn):
    tracemalloc.start()
    try:
        tracemalloc.reset_peak()
        out = fn()
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    return peak, out


def _ingest_metrics(info):
    n, m, chunk = 20_000, 400_000, 16_384
    rng = np.random.default_rng(0)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = np.ones(m, np.float32)

    def chunks():
        for i in range(0, m, chunk):
            yield src[i:i + chunk], dst[i:i + chunk], w[i:i + chunk]

    peak_chunked, g = _peak(
        lambda: csr_from_chunks(chunks, n=n, block_edges=chunk))
    peak_dense, _ = _peak(lambda: CSRGraph.from_edges(n, src, dst, w))
    out_bytes = g.row_ptr.nbytes + g.col.nbytes + g.wgt.nbytes
    info["ingest_peak_chunked_bytes"] = peak_chunked
    info["ingest_peak_dense_bytes"] = peak_dense
    return {
        # allocation sizes are deterministic, so these ratios are exact
        "ingest_peak_over_output": peak_chunked / out_bytes,
        "ingest_chunked_over_dense_peak": peak_chunked / peak_dense,
    }


def _layout_metrics(g):
    base = walk_collective_bytes(8, 512, g.max_degree, 20)
    cache = walk_collective_bytes(8, 512, CAP, 20)
    csr_bytes = g.row_ptr.nbytes + g.col.nbytes + g.wgt.nbytes
    return {
        "coll_bytes_cache_over_base": cache / base,
        "transition_table_over_csr_bytes":
            g.transition_table_bytes() / csr_bytes,
    }


def _overlap_metrics(g):
    # analytic superstep-pipeline model (roofline.traffic.walk_overlap_model)
    # at 8 shards, one walker per vertex, length 20 — pure arithmetic over
    # the layout, so these ratios are exact and regression-gated strictly
    shards, length = 8, 20
    n_local = -(-g.n // shards)
    barrier = walk_overlap_model(shards, n_local, CAP, length,
                                 walkers_per_shard=n_local, pipeline=False)
    pipe = walk_overlap_model(shards, (n_local + 1) // 2, CAP, length,
                              walkers_per_shard=n_local, pipeline=True)
    return {
        "overlap_exposed_over_barrier":
            pipe["exposed_bytes"] / barrier["exposed_bytes"],
        "overlap_efficiency_pipelined": pipe["efficiency"],
    }


def _walk_metrics(g, info):
    kw = dict(p=0.5, q=2.0, length=10, cap=CAP)
    engines = {
        "exact": WalkEngine.build(g, WalkPlan(mode="exact", **kw)),
        "approx": WalkEngine.build(
            g, WalkPlan(mode="approx_always", approx_eps=5e-2, **kw)),
        "fused": WalkEngine.build(g, WalkPlan(backend="fused", **kw)),
    }
    us = {name: time_fn(lambda e=e: e.run(seed=0).walks, warmup=1, iters=3)
          for name, e in engines.items()}
    info.update({f"walk_us_{k}": v for k, v in us.items()})
    return {
        "walk_us_approx_over_exact": us["approx"] / us["exact"],
        "walk_us_fused_over_reference": us["fused"] / us["exact"],
    }


def run(out_path: str = "BENCH_smoke.json") -> dict:
    info: dict = {}
    g = graph(SKEW_SPEC)
    info["graph"] = {"spec": SKEW_SPEC, "n": g.n, "m": g.m,
                     "max_degree": g.max_degree}
    metrics = {}
    metrics.update(_ingest_metrics(info))
    metrics.update(_layout_metrics(g))
    metrics.update(_overlap_metrics(g))
    metrics.update(_walk_metrics(g, info))
    from benchmarks.bench_serve import smoke_metrics as _serve_metrics
    metrics.update(_serve_metrics(info))
    from benchmarks.bench_train import smoke_metrics as _train_metrics
    metrics.update(_train_metrics(info))
    from benchmarks.bench_update import smoke_metrics as _update_metrics
    metrics.update(_update_metrics(info))
    doc = {"version": 1, "metrics": metrics, "info": info}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    for k in sorted(metrics):
        print(f"{k} = {metrics[k]:.4g}")
    print(f"wrote {out_path}")
    return doc


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_smoke.json")
