"""Streamed walk→SGNS training benchmark (DESIGN.md §14).

Battery mode (``run()``, wired into ``benchmarks.run``) prints the usual
``name,us_per_call,derived`` CSV rows: end-to-end walk+train wall time for
the streamed on-device pipeline vs. the two generate-then-train baselines
(host corpus path, and the same device trainer without overlap), plus the
fused-kernel vs jnp per-step ratio.

Smoke mode (``--smoke [out.json]``) merges **ratio** metrics into the
``BENCH_smoke.json`` schema, gated by ``scripts/bench_compare.py --strict
--only train_`` (``make train-smoke``):

* ``train_stream_over_concat_us``   — end-to-end wall ratio of the streamed
                                      pipeline over generate-then-train
                                      through the host corpus path
                                      (interleaved runs; machine load
                                      cancels; < 1 means streaming wins).
* ``train_h2d_stream_over_concat``  — host→device bytes of the streamed
                                      path over the per-batch staging the
                                      host path uploads. Deterministic
                                      layout arithmetic — exact.
* ``train_fused_over_jnp_step_us``  — per-train-step wall ratio of the
                                      fused Pallas SGNS backend over jnp
                                      autodiff (interpret mode off-TPU, so
                                      > 1 here; on TPU the kernel is the
                                      arithmetic-intensity floor).
* ``train_shard_pairs_ratio``       — sharded-trainer (2 table shards)
                                      pairs/sec over the dense single-device
                                      trainer on the same rounds, measured
                                      in a 2-virtual-device subprocess on a
                                      vocabulary whose tables fit either
                                      way. The ISSUE-10 acceptance gate
                                      asserts >= 1.5x: the win is lazy
                                      row-Adam's O(rows·D) step vs dense
                                      Adam's O(V·D), not fake-device
                                      parallelism (one physical core here).
* ``train_shard_bit_identical``     — 1.0 iff the sharded trainer at 2
                                      shards reproduces the 1-shard run bit
                                      for bit (embeddings + loss history),
                                      jnp and fused backends.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import graph, row, time_fn
from repro.core.node2vec import Node2VecConfig, generate_walks, \
    train_embeddings
from repro.core.skipgram import SGNSConfig, init_params, train_step
from repro.optim.optimizers import adam
from repro.runtime.fault_tolerance import WalkRoundRunner
from repro.train import StreamingSGNSTrainer

SPEC = "wec:k=9,deg=12,seed=1"
CFG = dict(p=0.5, q=2.0, walk_length=16, num_walks=3, window=5, dim=32,
           negatives=5, batch_size=512, seed=0)


def _cfg(**kw) -> Node2VecConfig:
    return Node2VecConfig(**{**CFG, **kw})


def _run_stream(g, cfg, backend: str = "jnp"):
    """Streamed pipeline: trainer consumes the runner's dispatch-ahead
    rounds (round k+1 walks while round k trains)."""
    trainer = StreamingSGNSTrainer.from_config(g.n, cfg,
                                               sgns_backend=backend,
                                               record_loss=False)
    t0 = time.perf_counter()
    _, stats = trainer.train(WalkRoundRunner(g, cfg).rounds())
    return time.perf_counter() - t0, stats


def _run_concat_host(g, cfg):
    """Generate-then-train through the host corpus path (the pre-streaming
    pipeline: np corpus, np pair expansion, per-batch H2D staging)."""
    t0 = time.perf_counter()
    walks = generate_walks(g, cfg)
    train_embeddings(g, walks, cfg)
    return time.perf_counter() - t0


def _run_concat_dev(g, cfg):
    """Generate-then-train through the *same* device trainer (no overlap):
    isolates the overlap win from the on-device-corpus win."""
    trainer = StreamingSGNSTrainer.from_config(g.n, cfg, record_loss=False)
    t0 = time.perf_counter()
    rounds = list(WalkRoundRunner(g, cfg).rounds())
    _, stats = trainer.train(iter(rounds))
    return time.perf_counter() - t0, stats


def _step_us(backend: str, cfg) -> float:
    """Per-train-step wall time for one fixed batch (5-step chain per call
    so the donated-buffer contract is exercised, init cost amortized)."""
    scfg = SGNSConfig(vocab=512, dim=cfg.dim, negatives=cfg.negatives)
    opt = adam(cfg.lr)
    rng = np.random.default_rng(0)
    batch = {
        "center": np.asarray(rng.integers(0, 512, cfg.batch_size), np.int32),
        "pos": np.asarray(rng.integers(0, 512, cfg.batch_size), np.int32),
        "neg": np.asarray(
            rng.integers(0, 512, (cfg.batch_size, cfg.negatives)), np.int32),
        "valid": np.ones(cfg.batch_size, np.float32),
    }

    def chain():
        params = init_params(scfg, jax.random.PRNGKey(0))
        state = opt.init(params)
        for _ in range(5):
            params, state, loss = train_step(params, state, batch, opt,
                                             backend)
        return loss

    return time_fn(chain, warmup=1, iters=3) / 5


# Runs in a 2-virtual-device subprocess (XLA_FLAGS in the parent env):
# times the dense single-device trainer vs the sharded trainer at 1 and 2
# table shards on identical synthetic rounds, and checks S=1 vs S=2
# bit-identity (embeddings + loss history, jnp and fused) on a small odd
# vocabulary so the pad-row path is exercised. Emits one "RESULT {json}"
# line. V=65536 makes dense Adam's O(V*D) per-step table work dominate,
# which is exactly the cost the lazy row-Adam path avoids.
_SHARD_SCRIPT = r"""
import json, sys, time
import numpy as np
import jax

from repro.launch.mesh import make_table_mesh
from repro.train import StreamingSGNSTrainer, train_epoch_sharded

assert jax.device_count() >= 2, jax.devices()

V, D, B, K, WINDOW = 65536, 64, 1024, 5, 4
ROUNDS, WALKERS, STEPS = 3, 1024, 9
rng = np.random.default_rng(0)
rounds = [np.asarray(rng.integers(0, V, (WALKERS, STEPS)), np.int32)
          for _ in range(ROUNDS)]


def trainer(**kw):
    return StreamingSGNSTrainer(V, dim=D, window=WINDOW, negatives=K,
                                batch_size=B, record_loss=False, **kw)


def timed(make):
    t0 = time.perf_counter()
    _, st = make().train(iter(rounds))
    return time.perf_counter() - t0, st


mk_dense = lambda: trainer()
mk_s1 = lambda: trainer(shard_tables=True, mesh=make_table_mesh(max_shards=1))
mk_s2 = lambda: trainer(shard_tables=True, mesh=make_table_mesh(max_shards=2))

for mk in (mk_dense, mk_s1, mk_s2):   # warmup: compile every program
    timed(mk)
t_d, t_1, t_2, st2 = [], [], [], None
for _ in range(2):                    # interleaved passes; load cancels
    t_d.append(timed(mk_dense)[0])
    t_1.append(timed(mk_s1)[0])
    dt, st2 = timed(mk_s2)
    t_2.append(dt)
pairs = st2.pairs

# bit-identity battery: small odd vocab -> pad row live on both tables
bit = 1.0
for backend in ("jnp", "fused"):
    embs, hists = [], []
    for s in (1, 2):
        tr = StreamingSGNSTrainer(
            257, dim=16, window=3, negatives=3, batch_size=256,
            sgns_backend=backend, shard_tables=True,
            mesh=make_table_mesh(max_shards=s))
        rng_b = np.random.default_rng(7)
        emb, _ = tr.train(iter(
            np.asarray(rng_b.integers(0, 257, (64, 9)), np.int32)
            for _ in range(2)))
        embs.append(np.asarray(emb))
        hists.append(tr.loss_history())
    if embs[0].tobytes() != embs[1].tobytes() or \
            hists[0].tobytes() != hists[1].tobytes():
        bit = 0.0
        print(f"BIT MISMATCH backend={backend}", file=sys.stderr)

print("RESULT " + json.dumps({
    "pps_dense": pairs / min(t_d),
    "pps_shard1": pairs / min(t_1),
    "pps_shard2": pairs / min(t_2),
    "bit_identical": bit,
    "collective_bytes": st2.collective_bytes,
    "compiles": train_epoch_sharded._cache_size(),
}))
"""


def _shard_subprocess() -> dict | None:
    """Run ``_SHARD_SCRIPT`` under 2 virtual CPU devices; None on failure."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-2000:])
        return None
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("RESULT ")]
    return json.loads(lines[-1][len("RESULT "):]) if lines else None


def _interleaved(g, cfg):
    """stream / concat-host / concat-dev, two interleaved passes each (min
    of the post-warmup passes; load cancels in the ratios)."""
    _run_stream(g, cfg)            # warmup: compiles walk + train programs
    _run_concat_host(g, cfg)
    _run_concat_dev(g, cfg)
    t_s, t_ch, t_cd, stats = [], [], [], None
    for _ in range(2):
        dt, stats = _run_stream(g, cfg)
        t_s.append(dt)
        t_ch.append(_run_concat_host(g, cfg))
        t_cd.append(_run_concat_dev(g, cfg)[0])
    return min(t_s), min(t_ch), min(t_cd), stats


def run() -> None:
    g = graph(SPEC)
    cfg = _cfg()
    t_s, t_ch, t_cd, st = _interleaved(g, cfg)
    row("train_stream", t_s * 1e6,
        f"pairs_per_sec={st.pairs / t_s:.0f};"
        f"tokens_per_sec={st.tokens / t_s:.0f};"
        f"overlap_efficiency={st.overlap_efficiency:.2f}")
    row("train_concat_host", t_ch * 1e6,
        f"stream_speedup={t_ch / t_s:.2f}x")
    row("train_concat_dev", t_cd * 1e6,
        f"overlap_only_speedup={t_cd / t_s:.2f}x")
    jnp_us = _step_us("jnp", cfg)
    fused_us = _step_us("fused", cfg)
    row("train_step_jnp", jnp_us, "")
    row("train_step_fused", fused_us,
        f"fused_over_jnp={fused_us / jnp_us:.2f}x (interpret off-TPU)")
    res = _shard_subprocess()
    if res is None:
        row("train_shard2", 0, "subprocess_failed")
        return
    row("train_shard2", 0,
        f"pairs_per_sec={res['pps_shard2']:.0f};"
        f"over_dense={res['pps_shard2'] / res['pps_dense']:.2f}x;"
        f"bit_identical={res['bit_identical']:.0f};"
        f"collective_bytes={res['collective_bytes']}")


def smoke_metrics(info: dict) -> dict:
    """The ratio metrics described in the module docstring."""
    g = graph(SPEC)
    cfg = _cfg()
    t_s, t_ch, t_cd, st = _interleaved(g, cfg)
    info.update({
        "train_stream_s": t_s,
        "train_concat_host_s": t_ch,
        "train_concat_dev_s": t_cd,
        "train_pairs": st.pairs,
        "train_steps": st.steps,
        "train_pairs_per_sec": st.pairs / t_s,
        "train_tokens_per_sec": st.tokens / t_s,
        "train_overlap_efficiency": st.overlap_efficiency,
    })
    jnp_us = _step_us("jnp", cfg)
    fused_us = _step_us("fused", cfg)
    info["train_step_jnp_us"] = jnp_us
    info["train_step_fused_us"] = fused_us
    res = _shard_subprocess()
    assert res is not None, "sharded 2-device subprocess failed"
    ratio = res["pps_shard2"] / res["pps_dense"]
    # ISSUE-10 acceptance gates, enforced here (not just by bench_compare
    # drift): the sharded trainer must reproduce the 1-shard run bit for
    # bit AND beat the dense trainer's pairs/sec by >= 1.5x on 2 devices.
    assert res["bit_identical"] == 1.0, "sharded run not bit-identical"
    assert ratio >= 1.5, f"shard2/dense pairs/sec {ratio:.2f} < 1.5"
    info.update({
        "train_shard_pps_dense": res["pps_dense"],
        "train_shard_pps_shard1": res["pps_shard1"],
        "train_shard_pps_shard2": res["pps_shard2"],
        "train_shard_collective_bytes": res["collective_bytes"],
        "train_shard_epoch_compiles": res["compiles"],
    })
    return {
        "train_stream_over_concat_us": t_s / t_ch,
        "train_h2d_stream_over_concat":
            st.h2d_bytes / st.h2d_bytes_concat,
        "train_fused_over_jnp_step_us": fused_us / jnp_us,
        "train_shard_pairs_ratio": ratio,
        "train_shard_bit_identical": res["bit_identical"],
    }


def run_smoke(out_path: str = "BENCH_smoke.json") -> dict:
    """Merge train metrics into ``out_path`` (existing walk/serve metrics,
    if the file is already there, are preserved)."""
    try:
        with open(out_path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        doc = {"version": 1, "metrics": {}, "info": {}}
    info = doc.setdefault("info", {})
    metrics = smoke_metrics(info)
    doc.setdefault("metrics", {}).update(metrics)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    for k in sorted(metrics):
        print(f"{k} = {metrics[k]:.4g}")
    print(f"wrote {out_path}")
    return doc


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--smoke"]
        run_smoke(args[0] if args else "BENCH_smoke.json")
    else:
        run()
