"""Streamed walk→SGNS training benchmark (DESIGN.md §14).

Battery mode (``run()``, wired into ``benchmarks.run``) prints the usual
``name,us_per_call,derived`` CSV rows: end-to-end walk+train wall time for
the streamed on-device pipeline vs. the two generate-then-train baselines
(host corpus path, and the same device trainer without overlap), plus the
fused-kernel vs jnp per-step ratio.

Smoke mode (``--smoke [out.json]``) merges **ratio** metrics into the
``BENCH_smoke.json`` schema, gated by ``scripts/bench_compare.py --strict
--only train_`` (``make train-smoke``):

* ``train_stream_over_concat_us``   — end-to-end wall ratio of the streamed
                                      pipeline over generate-then-train
                                      through the host corpus path
                                      (interleaved runs; machine load
                                      cancels; < 1 means streaming wins).
* ``train_h2d_stream_over_concat``  — host→device bytes of the streamed
                                      path over the per-batch staging the
                                      host path uploads. Deterministic
                                      layout arithmetic — exact.
* ``train_fused_over_jnp_step_us``  — per-train-step wall ratio of the
                                      fused Pallas SGNS backend over jnp
                                      autodiff (interpret mode off-TPU, so
                                      > 1 here; on TPU the kernel is the
                                      arithmetic-intensity floor).
"""
from __future__ import annotations

import json
import sys
import time

import jax
import numpy as np

from benchmarks.common import graph, row, time_fn
from repro.core.node2vec import Node2VecConfig, generate_walks, \
    train_embeddings
from repro.core.skipgram import SGNSConfig, init_params, train_step
from repro.optim.optimizers import adam
from repro.runtime.fault_tolerance import WalkRoundRunner
from repro.train import StreamingSGNSTrainer

SPEC = "wec:k=9,deg=12,seed=1"
CFG = dict(p=0.5, q=2.0, walk_length=16, num_walks=3, window=5, dim=32,
           negatives=5, batch_size=512, seed=0)


def _cfg(**kw) -> Node2VecConfig:
    return Node2VecConfig(**{**CFG, **kw})


def _run_stream(g, cfg, backend: str = "jnp"):
    """Streamed pipeline: trainer consumes the runner's dispatch-ahead
    rounds (round k+1 walks while round k trains)."""
    trainer = StreamingSGNSTrainer.from_config(g.n, cfg,
                                               sgns_backend=backend,
                                               record_loss=False)
    t0 = time.perf_counter()
    _, stats = trainer.train(WalkRoundRunner(g, cfg).rounds())
    return time.perf_counter() - t0, stats


def _run_concat_host(g, cfg):
    """Generate-then-train through the host corpus path (the pre-streaming
    pipeline: np corpus, np pair expansion, per-batch H2D staging)."""
    t0 = time.perf_counter()
    walks = generate_walks(g, cfg)
    train_embeddings(g, walks, cfg)
    return time.perf_counter() - t0


def _run_concat_dev(g, cfg):
    """Generate-then-train through the *same* device trainer (no overlap):
    isolates the overlap win from the on-device-corpus win."""
    trainer = StreamingSGNSTrainer.from_config(g.n, cfg, record_loss=False)
    t0 = time.perf_counter()
    rounds = list(WalkRoundRunner(g, cfg).rounds())
    _, stats = trainer.train(iter(rounds))
    return time.perf_counter() - t0, stats


def _step_us(backend: str, cfg) -> float:
    """Per-train-step wall time for one fixed batch (5-step chain per call
    so the donated-buffer contract is exercised, init cost amortized)."""
    scfg = SGNSConfig(vocab=512, dim=cfg.dim, negatives=cfg.negatives)
    opt = adam(cfg.lr)
    rng = np.random.default_rng(0)
    batch = {
        "center": np.asarray(rng.integers(0, 512, cfg.batch_size), np.int32),
        "pos": np.asarray(rng.integers(0, 512, cfg.batch_size), np.int32),
        "neg": np.asarray(
            rng.integers(0, 512, (cfg.batch_size, cfg.negatives)), np.int32),
        "valid": np.ones(cfg.batch_size, np.float32),
    }

    def chain():
        params = init_params(scfg, jax.random.PRNGKey(0))
        state = opt.init(params)
        for _ in range(5):
            params, state, loss = train_step(params, state, batch, opt,
                                             backend)
        return loss

    return time_fn(chain, warmup=1, iters=3) / 5


def _interleaved(g, cfg):
    """stream / concat-host / concat-dev, two interleaved passes each (min
    of the post-warmup passes; load cancels in the ratios)."""
    _run_stream(g, cfg)            # warmup: compiles walk + train programs
    _run_concat_host(g, cfg)
    _run_concat_dev(g, cfg)
    t_s, t_ch, t_cd, stats = [], [], [], None
    for _ in range(2):
        dt, stats = _run_stream(g, cfg)
        t_s.append(dt)
        t_ch.append(_run_concat_host(g, cfg))
        t_cd.append(_run_concat_dev(g, cfg)[0])
    return min(t_s), min(t_ch), min(t_cd), stats


def run() -> None:
    g = graph(SPEC)
    cfg = _cfg()
    t_s, t_ch, t_cd, st = _interleaved(g, cfg)
    row("train_stream", t_s * 1e6,
        f"pairs_per_sec={st.pairs / t_s:.0f};"
        f"tokens_per_sec={st.tokens / t_s:.0f};"
        f"overlap_efficiency={st.overlap_efficiency:.2f}")
    row("train_concat_host", t_ch * 1e6,
        f"stream_speedup={t_ch / t_s:.2f}x")
    row("train_concat_dev", t_cd * 1e6,
        f"overlap_only_speedup={t_cd / t_s:.2f}x")
    jnp_us = _step_us("jnp", cfg)
    fused_us = _step_us("fused", cfg)
    row("train_step_jnp", jnp_us, "")
    row("train_step_fused", fused_us,
        f"fused_over_jnp={fused_us / jnp_us:.2f}x (interpret off-TPU)")


def smoke_metrics(info: dict) -> dict:
    """The ratio metrics described in the module docstring."""
    g = graph(SPEC)
    cfg = _cfg()
    t_s, t_ch, t_cd, st = _interleaved(g, cfg)
    info.update({
        "train_stream_s": t_s,
        "train_concat_host_s": t_ch,
        "train_concat_dev_s": t_cd,
        "train_pairs": st.pairs,
        "train_steps": st.steps,
        "train_pairs_per_sec": st.pairs / t_s,
        "train_tokens_per_sec": st.tokens / t_s,
        "train_overlap_efficiency": st.overlap_efficiency,
    })
    jnp_us = _step_us("jnp", cfg)
    fused_us = _step_us("fused", cfg)
    info["train_step_jnp_us"] = jnp_us
    info["train_step_fused_us"] = fused_us
    return {
        "train_stream_over_concat_us": t_s / t_ch,
        "train_h2d_stream_over_concat":
            st.h2d_bytes / st.h2d_bytes_concat,
        "train_fused_over_jnp_step_us": fused_us / jnp_us,
    }


def run_smoke(out_path: str = "BENCH_smoke.json") -> dict:
    """Merge train metrics into ``out_path`` (existing walk/serve metrics,
    if the file is already there, are preserved)."""
    try:
        with open(out_path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        doc = {"version": 1, "metrics": {}, "info": {}}
    info = doc.setdefault("info", {})
    metrics = smoke_metrics(info)
    doc.setdefault("metrics", {}).update(metrics)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    for k in sorted(metrics):
        print(f"{k} = {metrics[k]:.4g}")
    print(f"wrote {out_path}")
    return doc


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--smoke"]
        run_smoke(args[0] if args else "BENCH_smoke.json")
    else:
        run()
