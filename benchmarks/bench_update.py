"""Incremental-update benchmark: ``WalkEngine.update`` vs full rebuild.

The PR-9 tentpole claim: a delta batch touching a small fraction of the
shards patches the resident device layout (host CSR splice + affected-row
alias resplice) several times faster than rebuilding the whole FN-Cache
layout from the patched CSR — at **bit-identical** resulting walks. The
crossover battery (``run()``) shows where that stops being true: as churn
spreads across the graph (and starts flipping hot-set membership, forcing
relayouts) the advantage collapses toward 1x.

Battery mode prints the usual ``name,us_per_call,derived`` CSV rows, one
per churn scale. Update and rebuild timings are interleaved per batch —
each timed batch is *distinct* (re-applying one batch degenerates into
cheap repeat weight-updates) — so machine load cancels in the ratio.

Smoke mode (``--smoke [out.json]``) merges ratio / deterministic metrics
into the ``BENCH_smoke.json`` schema, gated by ``scripts/bench_compare.py
--strict`` and asserted against the ISSUE-9 acceptance bars directly:

* ``update_rebuild_over_update_us`` — full-rebuild-time / update-time for
                                  weight churn confined to the top-256
                                  degree ranks (<= 10% of shards under
                                  ``relabel=degree``). Gate: >= 3.
* ``update_invalidated_shard_fraction`` — WalkStats-reported fraction of
                                  device shards invalidated by that churn
                                  (deterministic). Gate: <= 0.10.
* ``update_bit_identical``        — 1.0 iff the updated engine's walks
                                  equal a from-scratch engine's at the
                                  same store version (exact).
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import row
from repro.data import open_graph
from repro.data.deltas import weight_churn, zipf_churn
from repro.engine import WalkEngine, WalkPlan

SPEC_BASE = "rmat:k=13,deg=16,seed=0"      # 8192 vertices, ~131k edges
SPEC = SPEC_BASE + ",relabel=degree"
CAP = 16
TOP = 256                                   # churn prefix -> <= 10% shards
LENGTH = 8
SMOKE_BATCHES = 5


def _plan() -> WalkPlan:
    return WalkPlan(p=0.5, q=2.0, length=LENGTH, cap=CAP)


def _block(pg) -> None:
    import jax
    jax.block_until_ready((pg.adj, pg.wgt, pg.alias_p, pg.hot_wgt))


def _timed_churn(batches, warmup_batch=None):
    """Interleaved per-batch timing: engine.update on a live engine vs
    WalkEngine.build from a shadow store held at the same version.

    Returns (update_us, rebuild_us, relayouts, updated_engine,
    fresh_engine_at_final_version)."""
    eng = WalkEngine.build(SPEC, _plan())
    _block(eng.pg)
    shadow = open_graph(SPEC)
    if warmup_batch is not None:            # touch both paths once untimed
        eng.update(warmup_batch)
        shadow.apply(warmup_batch)
        _block(WalkEngine.build(shadow, _plan()).pg)
    t_up, t_reb, relayouts = [], [], 0
    fresh = None
    for b in batches:
        t0 = time.perf_counter()
        rep = eng.update(b)
        _block(eng.pg)
        t_up.append(time.perf_counter() - t0)
        relayouts += int(rep.relayout)

        shadow.apply(b)
        t0 = time.perf_counter()
        fresh = WalkEngine.build(shadow, _plan())
        _block(fresh.pg)
        t_reb.append(time.perf_counter() - t0)
    return (float(np.sum(t_up) * 1e6), float(np.sum(t_reb) * 1e6),
            relayouts, eng, fresh)


def _weight_batches(num: int, seed: int = 0, top: int = TOP,
                    batch_edges: int = 128):
    """Weight-only churn in ORIGINAL ids (the store remaps through the
    frozen degree perm) — the guaranteed no-relayout path."""
    g0 = open_graph(SPEC_BASE).graph
    return list(weight_churn(g0, num_batches=num, batch_edges=batch_edges,
                             seed=seed, top=top))


def run() -> None:
    # the gated steady-state path: weight churn on the hot prefix
    batches = _weight_batches(4, seed=0)
    up_us, reb_us, relayouts, eng, fresh = _timed_churn(
        batches[1:], warmup_batch=batches[0])
    res, ref = eng.run(seed=3), fresh.run(seed=3)
    bit = bool(np.array_equal(res.walks, ref.walks))
    row("update_weight_top256", up_us / len(batches[1:]),
        f"rebuild_us={reb_us / len(batches[1:]):.0f};"
        f"ratio={reb_us / up_us:.1f}x;"
        f"inv_frac={res.stats.invalidated_shard_fraction:.3f};"
        f"relayouts={relayouts};bit_identical={bit}")

    # the crossover: topology churn at widening scope — adds/removes flip
    # hot-set membership, relayouts kick in, and the advantage collapses
    g0 = open_graph(SPEC_BASE).graph
    for label, top, edges in [("top256", 256, 64),
                              ("top2048", 2048, 512),
                              ("global", None, 4096)]:
        bs = list(zipf_churn(g0, num_batches=3, batch_edges=edges, seed=1,
                             top=top))
        up_us, reb_us, relayouts, eng, fresh = _timed_churn(
            bs[1:], warmup_batch=bs[0])
        res, ref = eng.run(seed=3), fresh.run(seed=3)
        bit = bool(np.array_equal(res.walks, ref.walks))
        row(f"update_topo_{label}", up_us / 2,
            f"rebuild_us={reb_us / 2:.0f};ratio={reb_us / up_us:.1f}x;"
            f"inv_frac={res.stats.invalidated_shard_fraction:.3f};"
            f"relayouts={relayouts};bit_identical={bit}")


def smoke_metrics(info: dict) -> dict:
    """The gated metrics described in the module docstring."""
    batches = _weight_batches(SMOKE_BATCHES, seed=0)
    up_us, reb_us, relayouts, eng, fresh = _timed_churn(
        batches[1:], warmup_batch=batches[0])
    assert relayouts == 0, "weight-only churn must never force a relayout"

    res, ref = eng.run(seed=3), fresh.run(seed=3)
    bit = bool(np.array_equal(res.walks, ref.walks))
    inv = float(res.stats.invalidated_shard_fraction)
    ratio = reb_us / up_us

    assert bit, "updated engine diverged from from-scratch rebuild"
    assert inv <= 0.10, f"churn invalidated {inv:.1%} of shards (> 10%)"
    assert ratio >= 3.0, \
        f"update only {ratio:.1f}x faster than rebuild (< 3x gate)"

    info["update_us_per_batch"] = up_us / (SMOKE_BATCHES - 1)
    info["update_rebuild_us_per_batch"] = reb_us / (SMOKE_BATCHES - 1)
    info["update_graph_version"] = int(res.stats.graph_version)
    info["update_delta_edges"] = int(res.stats.delta_edges)
    return {
        "update_rebuild_over_update_us": ratio,
        "update_invalidated_shard_fraction": inv,
        "update_bit_identical": 1.0 if bit else 0.0,
    }


def run_smoke(out_path: str = "BENCH_smoke.json") -> dict:
    """Merge update metrics into ``out_path`` (existing metrics preserved)."""
    try:
        with open(out_path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        doc = {"version": 1, "metrics": {}, "info": {}}
    info = doc.setdefault("info", {})
    metrics = smoke_metrics(info)
    doc.setdefault("metrics", {}).update(metrics)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    for k in sorted(metrics):
        print(f"{k} = {metrics[k]:.4g}")
    print(f"wrote {out_path}")
    return doc


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--smoke"]
        run_smoke(args[0] if args else "BENCH_smoke.json")
    else:
        run()
