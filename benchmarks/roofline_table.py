"""Deliverable (g): the roofline table over every (arch x shape x mesh)
dry-run artifact. Reads experiments/dryrun/*.json; prints the three terms,
bottleneck, useful-compute ratio and roofline fraction per cell."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def load_artifacts(mesh: str | None = None, tag: str | None = None):
    arts = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if tag is None and len(parts) > 3:
            continue
        if tag is not None and (len(parts) < 4 or parts[3] != tag):
            continue
        with open(path) as f:
            arts.append(json.load(f))
    if mesh:
        arts = [a for a in arts if a.get("mesh") == mesh]
    return arts


def run():
    arts = load_artifacts()
    n_ok = n_skip = 0
    for a in arts:
        name = f"roofline_{a['arch']}_{a['shape']}_{a['mesh']}"
        if a.get("status") == "skipped":
            n_skip += 1
            row(name, 0.0, "skipped=" + a["reason"][:50].replace(",", ";"))
            continue
        n_ok += 1
        dom = a["bottleneck"]
        us = max(a["t_compute"], a["t_memory"], a["t_collective"]) * 1e6
        row(name, us,
            f"tc={a['t_compute']:.3e};tm={a['t_memory']:.3e};"
            f"tx={a['t_collective']:.3e};dom={dom};"
            f"useful={a['useful_ratio']:.2f};"
            f"frac={a['roofline_fraction']:.4f}")
    row("roofline_summary", 0.0, f"cells_ok={n_ok};cells_skipped={n_skip}")


if __name__ == "__main__":
    run()
