"""Paper §3.2 / Eq. 1 — memory: on-demand computation vs pre-computing all
2nd-order transition probabilities (8 * sum d_i^2 bytes). Derived: the
savings factor, plus the paper's own headline numbers for scale."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from benchmarks import common
from repro.core.graph import PaddedGraph


def _fast_node2vec_bytes(pg: PaddedGraph) -> int:
    total = 0
    import jax
    for leaf in jax.tree.leaves(pg):
        total += leaf.size * leaf.dtype.itemsize
    return total


def run():
    for name, g in [("wec12", common.graph("wec:k=12,deg=30,seed=0")),
                    ("skew4", common.graph("skew:s=4,k=11,deg=40,seed=0"))]:
        eq1 = g.transition_table_bytes()
        pg = PaddedGraph.build(g, cap=32)
        ours = _fast_node2vec_bytes(pg)
        row(f"memory_{name}", 0.0,
            f"precompute_eq1_bytes={eq1};ondemand_bytes={ours};"
            f"savings={eq1 / ours:.1f}x")
    # paper headline extrapolations (Eq. 1): n=1G, d=100 -> 80 TB; d=1000 -> 8 PB
    row("memory_paper_headline", 0.0,
        "n1e9_d100=80TB;n1e9_d1000=8PB;cluster_mem=1.5TB")


if __name__ == "__main__":
    run()
