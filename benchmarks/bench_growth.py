"""Paper Fig. 4/5 — message-memory growth over supersteps: walks drift toward
popular vertices, so per-superstep NEIG volume grows, then flattens. We
measure the exact quantity (bytes a push-based engine would move per step:
sum over walkers of deg(current vertex) x 8B) per superstep, plus the
hot-visit share trajectory — the effect FN-Cache/FN-Approx exploit."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from benchmarks import common
from repro.engine import WalkEngine, WalkPlan


def run():
    g = common.graph("skew:s=4,k=11,deg=40,seed=0")
    cap = 48
    eng = WalkEngine.build(g, WalkPlan(p=0.5, q=2.0, length=30))
    walks = eng.run(seed=0).walks
    deg = g.deg.astype(np.int64)
    hot = deg > cap
    # superstep 0 = walkers at their (uniform) start vertices — the paper's
    # Fig. 4 baseline; the first move already lands on degree-biased
    # neighbors (friendship paradox), then plateaus.
    starts = np.arange(g.n)
    first = int(deg[starts].sum() * 8)
    row("growth_start", 0.0,
        f"neig_bytes={first};vs_start=1.00x;"
        f"hot_visit_share={float(hot[starts].mean()):.3f}")
    for s in [0, 1, 2, 4, 8, 16, 29]:
        cur = walks[:, s]
        neig_bytes = int(deg[cur].sum() * 8)
        hot_share = float(hot[cur].mean())
        row(f"growth_step{s:02d}", 0.0,
            f"neig_bytes={neig_bytes};vs_start={neig_bytes / first:.2f}x;"
            f"hot_visit_share={hot_share:.3f}")
    # the flattening ratio (paper: memory grows then plateaus ~ step 10)
    mid = int(deg[walks[:, 8]].sum())
    late = int(deg[walks[:, 29]].sum())
    row("growth_plateau", 0.0,
        f"late_over_mid={late / max(mid, 1):.3f} (≈1.0 ⇒ plateaued)")


if __name__ == "__main__":
    run()
