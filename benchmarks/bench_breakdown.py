"""Paper Fig. 1 — Node2Vec runtime breakdown: random-walk stage vs SGNS
optimization stage. The paper reports 98.8% in the walk stage for
Spark-Node2Vec; our walk engine is far faster, so the split shifts — the
derived column reports the walk share we measure.

Also reports the superstep-pipeline overlap breakdown on the Skew-5
synthetic (EXPERIMENTS.md §Overlap): analytic exposed-vs-total NEIG bytes
for barrier vs double-buffered pipelined mode at 8 shards, plus measured
``WalkStats`` from a 2-virtual-device subprocess run of both modes."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import row
from benchmarks import common
from repro.core.node2vec import Node2VecConfig, train_embeddings
from repro.engine import WalkEngine
from repro.roofline.traffic import walk_overlap_model

SKEW5_SPEC = "skew:s=5,k=9,deg=20,seed=3"

_MEASURED_SCRIPT = r"""
import json, sys
import numpy as np, jax
from benchmarks import common
from repro.engine import WalkEngine, WalkPlan
from repro.launch.mesh import make_rw_mesh

g = common.graph(sys.argv[1])
mesh = make_rw_mesh(None)
out = {}
for name, pipe in (("barrier", False), ("pipelined", True)):
    plan = WalkPlan(p=1.0, q=2.0, length=20, cap=24, backend="sharded",
                    pipeline=pipe)
    res = WalkEngine.build(g, plan, mesh=mesh).run(seed=0)
    out[name] = {"exposed": res.stats.exposed_collective_bytes,
                 "total": res.stats.collective_bytes,
                 "efficiency": res.stats.overlap_efficiency,
                 "dropped": res.stats.dropped}
print("RESULT " + json.dumps(out))
"""


def run_overlap():
    g = common.graph(SKEW5_SPEC)
    shards, cap, length = 8, 24, 20
    n_local = -(-g.n // shards)
    barrier = walk_overlap_model(shards, n_local, cap, length,
                                 walkers_per_shard=n_local, pipeline=False)
    pipe = walk_overlap_model(shards, (n_local + 1) // 2, cap, length,
                              walkers_per_shard=n_local, pipeline=True)
    row("overlap_barrier_exposed_bytes", barrier["exposed_bytes"],
        f"total={barrier['total_bytes']} eff={barrier['efficiency']:.4f}")
    row("overlap_pipelined_exposed_bytes", pipe["exposed_bytes"],
        f"total={pipe['total_bytes']} eff={pipe['efficiency']:.4f} "
        f"exposed_over_barrier="
        f"{pipe['exposed_bytes'] / barrier['exposed_bytes']:.4f}")
    # measured WalkStats on 2 virtual devices (subprocess: XLA device count
    # is process-global, same pattern as the sharded parity tests)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-c", _MEASURED_SCRIPT, SKEW5_SPEC],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode:
        row("overlap_measured", 0, "subprocess_failed")
        print(proc.stderr[-2000:], file=sys.stderr)
        return
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    meas = json.loads(line[len("RESULT "):])
    for name in ("barrier", "pipelined"):
        m = meas[name]
        row(f"overlap_measured_{name}_exposed_bytes", m["exposed"],
            f"total={m['total']} eff={m['efficiency']:.4f} "
            f"dropped={m['dropped']}")


def run():
    g = common.graph("wec:k=10,deg=20,seed=0")
    cfg = Node2VecConfig(p=1.0, q=2.0, walk_length=40, num_walks=2, dim=32,
                         window=5, epochs=1, batch_size=4096)
    eng = WalkEngine.build(g, cfg.plan())
    # warmup compile
    eng.run(seed=0)
    t0 = time.perf_counter()
    walks = [r.walks for r in eng.rounds(cfg.num_walks, seed=cfg.seed)]
    t_walk = time.perf_counter() - t0
    walks = np.concatenate(walks, 0)
    t0 = time.perf_counter()
    train_embeddings(g, walks, cfg)
    t_sgd = time.perf_counter() - t0
    share = t_walk / (t_walk + t_sgd)
    row("breakdown_walk", t_walk * 1e6, f"walk_share={share:.3f}")
    row("breakdown_sgns", t_sgd * 1e6,
        f"paper_spark_walk_share=0.988")
    run_overlap()


if __name__ == "__main__":
    run()
