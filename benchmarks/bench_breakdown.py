"""Paper Fig. 1 — Node2Vec runtime breakdown: random-walk stage vs SGNS
optimization stage. The paper reports 98.8% in the walk stage for
Spark-Node2Vec; our walk engine is far faster, so the split shifts — the
derived column reports the walk share we measure."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from benchmarks import common
from repro.core.node2vec import Node2VecConfig, train_embeddings
from repro.engine import WalkEngine


def run():
    g = common.graph("wec:k=10,deg=20,seed=0")
    cfg = Node2VecConfig(p=1.0, q=2.0, walk_length=40, num_walks=2, dim=32,
                         window=5, epochs=1, batch_size=4096)
    eng = WalkEngine.build(g, cfg.plan())
    # warmup compile
    eng.run(seed=0)
    t0 = time.perf_counter()
    walks = [r.walks for r in eng.rounds(cfg.num_walks, seed=cfg.seed)]
    t_walk = time.perf_counter() - t0
    walks = np.concatenate(walks, 0)
    t0 = time.perf_counter()
    train_embeddings(g, walks, cfg)
    t_sgd = time.perf_counter() - t0
    share = t_walk / (t_walk + t_sgd)
    row("breakdown_walk", t_walk * 1e6, f"walk_share={share:.3f}")
    row("breakdown_sgns", t_sgd * 1e6,
        f"paper_spark_walk_share=0.988")


if __name__ == "__main__":
    run()
