.PHONY: ci fast bench

ci:            ## tier-1: full test suite (the per-PR bar)
	scripts/ci.sh tier1

fast:          ## tier-1 minus `slow` (distributed / subprocess) tests
	scripts/ci.sh fast

bench:         ## run the benchmark battery (CSV rows to stdout)
	PYTHONPATH=src python -m benchmarks.run
