.PHONY: ci fast smoke lint serve-smoke train-smoke train-shard-smoke \
	update-smoke bench \
	bench-smoke bench-baseline

ci:            ## tier-1: full test suite (the per-PR bar; nightly in CI)
	scripts/ci.sh tier1

fast:          ## tier-1 minus `slow` (distributed / subprocess) tests
	scripts/ci.sh fast

smoke:         ## per-push gate: lint + import + collect + fast unit subset
	scripts/ci.sh smoke

lint:          ## forbidden-API checks only (jax-0.4.37 quirks)
	scripts/ci.sh lint

serve-smoke:   ## serving end-to-end + gated serve_* ratios vs baseline
	scripts/ci.sh serve-smoke

train-smoke:   ## streamed walk→SGNS parity battery + gated train_* ratios
	scripts/ci.sh train-smoke

train-shard-smoke: ## sharded SGNS parity battery + gated train_shard_* ratios
	scripts/ci.sh train-shard-smoke

update-smoke:  ## delta/engine.update parity battery + gated update_* ratios
	scripts/ci.sh update-smoke

bench:         ## run the benchmark battery (CSV rows to stdout)
	PYTHONPATH=src python -m benchmarks.run

bench-smoke:   ## emit BENCH_smoke.json + compare ratios vs baseline (gate >2x)
	PYTHONPATH=src python -m benchmarks.bench_smoke BENCH_smoke.json
	python scripts/bench_compare.py BENCH_smoke.json \
	    benchmarks/baselines/BENCH_smoke.json --strict

bench-baseline: ## refresh the committed bench-smoke baseline
	PYTHONPATH=src python -m benchmarks.bench_smoke \
	    benchmarks/baselines/BENCH_smoke.json
