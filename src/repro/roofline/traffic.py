"""Analytic per-device HBM traffic model.

The CPU backend's ``cost_analysis()['bytes accessed']`` sums operand+result
bytes of every *unfused* HLO op — on this container it overstates true HBM
traffic by ~3 orders of magnitude (the TPU compiler fuses elementwise chains;
CPU does not). The dry-run therefore records the raw HLO number for reference
and uses this explicit, documented traffic model for the memory roofline term
(every term below is standard napkin math, kept in code so the §Perf
iterations can diff it):

train (per device, per step):
  weights    3 compute passes (fwd, remat-fwd, bwd) x param_bytes
  optimizer  7 x param_bytes (read p/m/v/g, write p/m/v) + 2 x grad
  acts       L x tokens_dev x d_model x bf16 x C   (C ~ 16 streams r+w)
  attn S^2   per attention layer: B_dev x H_dev x S x W x ~12 bytes
             (f32 logits w+r, bf16 probs w+r), W = min(S, window)
             -- the dominant train/prefill term without a flash kernel.
decode (per device, per step):
  weights    1 x param_bytes (every live weight read once)
  kv/state   cache bytes read (+ epsilon write)
"""
from __future__ import annotations

from repro.models.config import ModelConfig

BF16 = 2
F32 = 4


def walk_collective_bytes(num_shards: int, capacity: int, cap: int,
                          length: int, w_bytes: int = F32) -> int:
    """Analytic per-device NEIG-exchange bytes for one full walk
    (``WalkStats.collective_bytes``).

    Per superstep each device moves: the request buffer (S x C x 4B ids out)
    plus the response rows (S x C x cap x (4B ids + w_bytes weights), two
    tiled all_to_alls). Step 0 is purely local (walkers start co-located),
    so there are ``length - 1`` exchanging supersteps. This is the quantity
    the paper's Figs. 4/14 measure; the measured-from-HLO counterpart is
    ``WalkEngine.analyze()``.
    """
    ids = 4
    per_step = num_shards * capacity * (ids + cap * (ids + w_bytes))
    return per_step * max(length - 1, 0)


def _shards(mesh_shape: dict) -> tuple[int, int, int]:
    pod = mesh_shape.get("pod", 1)
    data = mesh_shape.get("data", 1)
    model = mesh_shape.get("model", 1)
    return pod, data, model


def param_bytes_per_device(cfg: ModelConfig, mesh_shape: dict) -> float:
    _, data, model = _shards(mesh_shape)
    return cfg.param_count() * F32 / (data * model)


def _attn_layers(cfg: ModelConfig) -> int:
    per = sum(1 for s in cfg.superblock()
              if s.kind in ("attn", "attn_cross"))
    n = per * cfg.num_superblocks
    if cfg.enc_layers:
        n += cfg.enc_layers
    return n


def _cache_bytes_global(cfg: ModelConfig, seq: int, batch: int) -> float:
    """KV caches + SSM states, global bytes."""
    total = 0.0
    eff = min(seq, cfg.window) if cfg.window else seq
    total += (_attn_layers(cfg) * batch * eff * cfg.num_kv_heads *
              cfg.head_dim * 2 * BF16)
    mamba_layers = sum(1 for s in cfg.superblock()
                       if s.kind == "mamba") * cfg.num_superblocks
    total += (mamba_layers * batch * cfg.ssm_heads * cfg.ssm_headdim *
              cfg.ssm_state * F32)
    cross_layers = sum(1 for s in cfg.superblock()
                       if s.kind in ("cross_attn", "attn_cross")
                       ) * cfg.num_superblocks
    if cross_layers:
        mem_len = (cfg.num_audio_frames if cfg.enc_layers
                   else cfg.num_image_tokens)
        total += (cross_layers * batch * mem_len * cfg.num_kv_heads *
                  cfg.head_dim * 2 * BF16)
    return total


def analytic_bytes(cfg: ModelConfig, kind: str, seq: int, batch: int,
                   mesh_shape: dict, flash_attention: bool = False) -> dict:
    """Per-device HBM bytes for one step; returns the breakdown."""
    pod, data, model = _shards(mesh_shape)
    batch_shards = pod * data
    chips = pod * data * model
    p_dev = param_bytes_per_device(cfg, mesh_shape)

    if kind == "decode":
        cache_dev = _cache_bytes_global(cfg, seq, batch) / chips
        return {"weights": p_dev, "cache": cache_dev,
                "acts": batch * cfg.d_model * BF16 * cfg.num_layers * 4
                / batch_shards,
                "attn_s2": 0.0,
                "total": p_dev + cache_dev}

    tokens_dev = batch * seq / batch_shards
    if kind == "train":
        weights = p_dev * 3          # fwd + remat fwd + bwd weight reads
        optimizer = p_dev * 9        # adam r/w + grads
    else:  # prefill
        weights = p_dev
        optimizer = 0.0
    act_streams = 16
    acts = (cfg.num_layers + cfg.enc_layers) * tokens_dev * cfg.d_model * \
        BF16 * act_streams / model if model else 0
    acts = acts * (3 if kind == "train" else 1)
    # attention score materialization (skipped if a flash kernel is fused)
    attn_s2 = 0.0
    if not flash_attention:
        eff = min(seq, cfg.window) if cfg.window else seq
        h_dev = max(cfg.num_heads / model, 1)
        b_dev = max(batch / batch_shards, 1)
        attn_s2 = _attn_layers(cfg) * b_dev * h_dev * seq * eff * 12.0
        attn_s2 *= (3 if kind == "train" else 1)
    cache_w = _cache_bytes_global(cfg, seq, batch) / chips \
        if kind == "prefill" else 0.0
    total = weights + optimizer + acts + attn_s2 + cache_w
    return {"weights": weights, "optimizer": optimizer, "acts": acts,
            "attn_s2": attn_s2, "cache": cache_w, "total": total}
