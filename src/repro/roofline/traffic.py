"""Analytic per-device HBM traffic model.

The CPU backend's ``cost_analysis()['bytes accessed']`` sums operand+result
bytes of every *unfused* HLO op — on this container it overstates true HBM
traffic by ~3 orders of magnitude (the TPU compiler fuses elementwise chains;
CPU does not). The dry-run therefore records the raw HLO number for reference
and uses this explicit, documented traffic model for the memory roofline term
(every term below is standard napkin math, kept in code so the §Perf
iterations can diff it):

train (per device, per step):
  weights    3 compute passes (fwd, remat-fwd, bwd) x param_bytes
  optimizer  7 x param_bytes (read p/m/v/g, write p/m/v) + 2 x grad
  acts       L x tokens_dev x d_model x bf16 x C   (C ~ 16 streams r+w)
  attn S^2   per attention layer: B_dev x H_dev x S x W x ~12 bytes
             (f32 logits w+r, bf16 probs w+r), W = min(S, window)
             -- the dominant train/prefill term without a flash kernel.
decode (per device, per step):
  weights    1 x param_bytes (every live weight read once)
  kv/state   cache bytes read (+ epsilon write)
"""
from __future__ import annotations

from typing import Optional

from repro.models.config import ModelConfig

BF16 = 2
F32 = 4


def walk_exchange_bytes(num_shards: int, capacity: int, cap: int,
                        w_bytes: int = F32) -> int:
    """Per-device bytes of ONE two-phase NEIG exchange: the request buffer
    (S x C x 4B ids out) plus the response rows (S x C x cap x (4B ids +
    w_bytes weights), two tiled all_to_alls)."""
    ids = 4
    return num_shards * capacity * (ids + cap * (ids + w_bytes))


def walk_collective_bytes(num_shards: int, capacity: int, cap: int,
                          length: int, w_bytes: int = F32) -> int:
    """Analytic per-device NEIG-exchange bytes for one full *barrier-mode*
    walk (``WalkStats.collective_bytes``).

    One exchange per superstep; step 0 is purely local (walkers start
    co-located), so there are ``length - 1`` exchanging supersteps. This is
    the quantity the paper's Figs. 4/14 measure; the measured-from-HLO
    counterpart is ``WalkEngine.analyze()``.
    """
    per_step = walk_exchange_bytes(num_shards, capacity, cap, w_bytes)
    return per_step * max(length - 1, 0)


def sgns_exchange_bytes(u_rows: int, dim: int, num_shards: int,
                        w_bytes: int = F32) -> int:
    """Analytic per-device collective bytes of ONE sharded-SGNS train step
    (``TrainStats.collective_bytes``; DESIGN.md §16).

    Each step moves two sparse row sets through owner-masked psums: the
    bucketed unique gather buffers out (each shard contributes its owned
    rows) and the same buffers route the combined rows back. A ring
    all-reduce moves ``2·(S−1)/S`` words per element per device, so for the
    bucketed ``u_rows × dim`` f32 buffers::

        bytes/device/step = 2 · (S−1)/S · u_rows · dim · 4

    Zero when ``num_shards <= 1`` (no wire). Like ``walk_exchange_bytes``
    this is napkin math kept in code — it feeds telemetry ratios, never an
    absolute-time gate.
    """
    if num_shards <= 1:
        return 0
    return int(2 * (num_shards - 1) / num_shards * u_rows * dim * w_bytes)


def walk_auto_capacity(deg, cap: Optional[int], num_shards: int,
                       walkers_per_shard: int, safety: float = 4.0,
                       floor: int = 8) -> int:
    """Derive a per-destination NEIG exchange capacity from the degree
    distribution (``WalkPlan.capacity="auto"``).

    Only *cold remote* vertices consume request slots: hot vertices are
    replicated everywhere (FN-Cache) and local vertices are read directly,
    so the zero-drop worst case — every walker asking the same destination,
    i.e. ``capacity = walkers_per_shard`` — is wildly pessimistic on skewed
    graphs, where most steps land on the (replicated) hot set. The walk's
    stationary visit probability of a vertex is proportional to its degree
    (undirected weighted chain), so the expected share of walkers standing
    on a cold vertex each step is the cold degree mass::

        cold_share = sum(deg[deg <= cap]) / sum(deg)

    and with hash-partitioned cold mass spread over ``num_shards``
    destinations, the expected per-destination demand per exchange is
    ``walkers_per_shard * cold_share / num_shards``. A ``safety`` multiplier
    (default 4x) covers burstiness; ``floor`` covers tiny shards. The result
    is clipped to ``walkers_per_shard`` (never worse than the zero-drop
    default). With ``cap=None`` (FN-Base: no hot set) every non-local step
    is a request, so cold_share is 1 and only the 1/num_shards spreading
    applies.
    """
    import numpy as np
    deg = np.asarray(deg, np.float64)
    total = deg.sum()
    if total <= 0 or num_shards < 1:
        return max(min(floor, walkers_per_shard), 1)
    cold_share = deg[deg <= cap].sum() / total if cap is not None else 1.0
    expected = walkers_per_shard * cold_share / num_shards
    auto = int(np.ceil(safety * expected))
    auto = max(auto, min(floor, walkers_per_shard), 1)
    return min(auto, walkers_per_shard)


def walk_step_flops(walkers: int, width: int) -> float:
    """Analytic per-device sampling FLOPs for one superstep over ``walkers``
    walkers with candidate rows of ``width`` lanes.

    Dominant terms per walker: the membership test (a [width x width]
    equality/any reduction of candidates against the carried prev row), plus
    O(width) alpha select / probs multiply / cumsum / compare-count lanes.
    Napkin math on purpose (same spirit as ``analytic_bytes`` above) — it
    only feeds the overlap model's hide-capacity estimate, never a pass/fail
    gate on absolute time.
    """
    return float(walkers) * (float(width) * float(width) + 8.0 * width)


def walk_step_bytes(walkers: int, width: int) -> float:
    """Analytic per-device HBM bytes of one superstep's sampling phase.

    The unfused jnp path materializes the membership booleans (a
    [walkers x width x width] broadcast, ~1B/lane) plus ~6 f32
    [walkers x width] streams (alpha, probs, cumsum read+write,
    compare-count) — see the node2vec_step kernel docstring. The walk step
    is memory-bound, so this (not FLOPs) is what sets the compute-phase
    duration the pipeline can hide an exchange behind.
    """
    return float(walkers) * (float(width) * float(width) + 24.0 * width)


def walk_overlap_model(num_shards: int, capacity: int, cap: int, length: int,
                       walkers_per_shard: int, pipeline: bool,
                       w_bytes: int = F32, width: Optional[int] = None,
                       peak_flops: Optional[float] = None,
                       hbm_bw: Optional[float] = None,
                       link_bw: Optional[float] = None) -> dict:
    """Analytic exposed-vs-total collective model for one walk.

    Barrier mode: every NEIG exchange sits on the superstep critical path —
    exposed == total, overlap efficiency 0.

    Pipelined mode (two walker cohorts A/B, double-buffered; see
    ``core.walk_distributed``): cohort B's step-k exchange is issued before
    cohort A's step-k compute, and A's step-(k+1) exchange before B's step-k
    compute, so each exchange can hide behind the other cohort's sampling
    work. Per overlapped exchange the *exposed* bytes are
    ``max(0, e - t_compute * LINK_BW)`` where ``e`` is the per-exchange
    bytes at the (per-cohort) capacity and ``t_compute`` is the roofline
    compute-time estimate of the hiding cohort's step — the max of its FLOP
    time and its HBM time (the step is memory-bound; ``walk_step_bytes``).
    The pipeline prologue (cohort A's step-1 exchange) has nothing to hide
    behind and stays fully exposed.

    Returns ``{"total_bytes", "exposed_bytes", "efficiency"}`` with
    ``efficiency = 1 - exposed/total`` (0 when nothing is on the wire).
    """
    from repro.roofline import analysis as roof
    peak_flops = peak_flops or roof.PEAK_FLOPS
    hbm_bw = hbm_bw or roof.HBM_BW
    link_bw = link_bw or roof.LINK_BW
    width = width or cap
    steps = max(length - 1, 0)
    if steps == 0 or num_shards <= 1:
        return {"total_bytes": 0, "exposed_bytes": 0, "efficiency": 0.0}
    if not pipeline:
        total = walk_exchange_bytes(num_shards, capacity, cap, w_bytes) * steps
        return {"total_bytes": total, "exposed_bytes": total,
                "efficiency": 0.0}
    w_a = (walkers_per_shard + 1) // 2          # cohort A = ceil half
    w_b = walkers_per_shard - w_a
    e = walk_exchange_bytes(num_shards, capacity, cap, w_bytes)

    def hide(w):
        t = max(walk_step_flops(w, width) / peak_flops,
                walk_step_bytes(w, width) / hbm_bw)
        return t * link_bw

    hide_a, hide_b = hide(w_a), hide(w_b)
    # A: 1 prologue (fully exposed) + steps-1 body exchanges hidden behind
    # B's compute; B: steps exchanges hidden behind A's compute.
    total = e * (2 * steps)
    exposed = e \
        + (steps - 1) * max(0.0, e - hide_b) \
        + steps * max(0.0, e - hide_a)
    return {"total_bytes": int(total), "exposed_bytes": int(exposed),
            "efficiency": 1.0 - exposed / total if total else 0.0}


def _shards(mesh_shape: dict) -> tuple[int, int, int]:
    pod = mesh_shape.get("pod", 1)
    data = mesh_shape.get("data", 1)
    model = mesh_shape.get("model", 1)
    return pod, data, model


def param_bytes_per_device(cfg: ModelConfig, mesh_shape: dict) -> float:
    _, data, model = _shards(mesh_shape)
    return cfg.param_count() * F32 / (data * model)


def _attn_layers(cfg: ModelConfig) -> int:
    per = sum(1 for s in cfg.superblock()
              if s.kind in ("attn", "attn_cross"))
    n = per * cfg.num_superblocks
    if cfg.enc_layers:
        n += cfg.enc_layers
    return n


def _cache_bytes_global(cfg: ModelConfig, seq: int, batch: int) -> float:
    """KV caches + SSM states, global bytes."""
    total = 0.0
    eff = min(seq, cfg.window) if cfg.window else seq
    total += (_attn_layers(cfg) * batch * eff * cfg.num_kv_heads *
              cfg.head_dim * 2 * BF16)
    mamba_layers = sum(1 for s in cfg.superblock()
                       if s.kind == "mamba") * cfg.num_superblocks
    total += (mamba_layers * batch * cfg.ssm_heads * cfg.ssm_headdim *
              cfg.ssm_state * F32)
    cross_layers = sum(1 for s in cfg.superblock()
                       if s.kind in ("cross_attn", "attn_cross")
                       ) * cfg.num_superblocks
    if cross_layers:
        mem_len = (cfg.num_audio_frames if cfg.enc_layers
                   else cfg.num_image_tokens)
        total += (cross_layers * batch * mem_len * cfg.num_kv_heads *
                  cfg.head_dim * 2 * BF16)
    return total


def analytic_bytes(cfg: ModelConfig, kind: str, seq: int, batch: int,
                   mesh_shape: dict, flash_attention: bool = False) -> dict:
    """Per-device HBM bytes for one step; returns the breakdown."""
    pod, data, model = _shards(mesh_shape)
    batch_shards = pod * data
    chips = pod * data * model
    p_dev = param_bytes_per_device(cfg, mesh_shape)

    if kind == "decode":
        cache_dev = _cache_bytes_global(cfg, seq, batch) / chips
        return {"weights": p_dev, "cache": cache_dev,
                "acts": batch * cfg.d_model * BF16 * cfg.num_layers * 4
                / batch_shards,
                "attn_s2": 0.0,
                "total": p_dev + cache_dev}

    tokens_dev = batch * seq / batch_shards
    if kind == "train":
        weights = p_dev * 3          # fwd + remat fwd + bwd weight reads
        optimizer = p_dev * 9        # adam r/w + grads
    else:  # prefill
        weights = p_dev
        optimizer = 0.0
    act_streams = 16
    acts = (cfg.num_layers + cfg.enc_layers) * tokens_dev * cfg.d_model * \
        BF16 * act_streams / model if model else 0
    acts = acts * (3 if kind == "train" else 1)
    # attention score materialization (skipped if a flash kernel is fused)
    attn_s2 = 0.0
    if not flash_attention:
        eff = min(seq, cfg.window) if cfg.window else seq
        h_dev = max(cfg.num_heads / model, 1)
        b_dev = max(batch / batch_shards, 1)
        attn_s2 = _attn_layers(cfg) * b_dev * h_dev * seq * eff * 12.0
        attn_s2 *= (3 if kind == "train" else 1)
    cache_w = _cache_bytes_global(cfg, seq, batch) / chips \
        if kind == "prefill" else 0.0
    total = weights + optimizer + acts + attn_s2 + cache_w
    return {"weights": weights, "optimizer": optimizer, "acts": acts,
            "attn_s2": attn_s2, "cache": cache_w, "total": total}
