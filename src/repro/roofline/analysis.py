"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Per (arch x shape x mesh) cell, three terms in seconds:

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_operand_bytes / (chips * LINK_BW)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

Because ``cost_analysis`` does NOT multiply through ``while`` loops (verified
empirically: scan length does not change reported flops), the dry-run compiles
the model at 1 and 2 superblocks *unrolled* and extrapolates linearly —
exact for a homogeneous stack:  cost(N) = c1 + (N-1) * (c2 - c1).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# "%name = TYPE[dims]{layout} opcode(...), replica_groups=[g,k]<=[n] ..."
_LINE_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}


def cost_dict(ca) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` output: some jax versions
    return a dict, others (e.g. 0.4.37) a one-element list of dicts."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-device *operand* bytes per collective opcode.

    HLO text prints only the result shape, so operand bytes are recovered
    from the result + the op semantics: all-gather result = group_size x
    operand; reduce-scatter operand = group_size x result; all-reduce /
    all-to-all / collective-permute result == operand. Async -start/-done
    pairs are counted once.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group(3) == "-done":
            continue
        result, op = m.group(1), m.group(2)
        rbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result))
        gm = _GROUPS_RE.search(line)
        gsize = int(gm.group(2)) if gm else 1
        if op == "all-gather" and gsize:
            rbytes = rbytes // max(gsize, 1)
        elif op == "reduce-scatter":
            rbytes = rbytes * gsize
        out[op] += rbytes
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    """Cost-analysis numbers are PER-DEVICE (verified: an SPMD module's
    cost_analysis reports the per-device program), so:

        HLO_FLOPs_total = hlo_flops * chips, and
        t_compute = HLO_FLOPs_total / (chips * peak) = hlo_flops / peak.
    """
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float           # per device
    hlo_bytes: float           # per device
    coll_bytes: float          # per device
    coll_by_op: Dict[str, int]
    model_flops: float         # global (6*N*D)
    per_device_mem: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_total — how much compiled compute is
        'useful' (catches remat recompute / replication / routing waste)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """ideal compute-only time vs the max roofline term (the score)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / t if t > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "coll_bytes": self.coll_bytes,
            "coll_by_op": self.coll_by_op, "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_mem": self.per_device_mem,
        }


def extrapolate(c1: dict, c2: dict, n: int) -> dict:
    """cost(N) = c1 + (N-1)*(c2 - c1), per numeric key (homogeneous stack)."""
    out = {}
    for k in c1:
        v1 = c1.get(k, 0)
        v2 = c2.get(k, 0)
        if isinstance(v1, dict):
            out[k] = extrapolate(v1, v2 if isinstance(v2, dict) else {}, n)
        else:
            out[k] = (v1 or 0) + (n - 1) * ((v2 or 0) - (v1 or 0))
    return out


def model_flops_for(cfg, kind: str, seq: int, global_batch: int) -> float:
    """6*N*D (dense) / 6*N_active*D for training; 2*N*D forward-only.
    D = processed tokens. Decode processes one token per call."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq * global_batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * global_batch
        return 2.0 * n_active * tokens
    tokens = global_batch  # decode: one new token per sequence
    return 2.0 * n_active * tokens
