"""Streaming graph ingestion + the dataset registry (DESIGN.md §10).

The paper's evaluation runs on on-disk edge lists (LiveJournal, com-Orkut,
Twitter); this module is how those workloads reach the WalkEngine without
the Eq. 1-style memory blowup of materializing O(m) Python objects:

* :func:`csr_from_chunks` — chunked, memory-bounded two-pass edge-list →
  CSR builder. Pass 1 streams chunks and counts degrees (O(n) state);
  pass 2 counting-sorts edges into the **preallocated** ``indptr``/``col``/
  ``wgt`` arrays; a final streaming block pass sorts + dedups rows in
  place. Peak transient allocation is O(n + chunk), never O(m) beyond the
  CSR output itself (asserted by ``tests/test_ingest.py`` with tracemalloc).

* :func:`save_csr` / :func:`load_csr` — binary CSR disk cache (``.npy``
  arrays + ``meta.json``); loads are ``np.memmap``-backed so a cached
  billion-edge graph costs page-cache, not RSS.

* :func:`load_graph` / :func:`load_dataset` — one spec-string registry over
  the synthetic families and on-disk sources::

      "er:k=10,deg=10,seed=0"        "wec:k=12,deg=100"
      "skew:s=3,k=10,deg=30"         "rmat:k=18,deg=16,a=0.45,b=0.22,c=0.22"
      "sbm:n=400,c=4,pin=0.06,pout=0.01"
      "edgelist:/path/graph.txt"     "edgelist:/path/graph.txt,n=4096"
      "csr:/path/cache_dir"

  ``relabel=degree`` is understood by every family; ``seed=<int>`` by the
  synthetic ones. ``edgelist:`` additionally takes ``n=``, ``directed=1``,
  ``dedup=0``, ``chunk=<edges>``; pass ``cache_dir=`` to
  :func:`load_graph` to build once and memmap thereafter. Unknown options
  are rejected, not ignored.

* :func:`relabel_by_degree` — degree-descending vertex relabeling: the
  FN-Cache hot set becomes the contiguous id prefix ``[0, K)`` and
  range-partitioned shards are degree-balanced (hubs spread by the
  round-robin-ish tail, not clustered by RMAT quadrant).

New families plug in via :func:`register_family`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Callable, Iterable, Iterator, Optional, Tuple

import numpy as np

from repro.core import rmat
from repro.core.graph import CSRGraph

DEFAULT_CHUNK_EDGES = 1 << 18
CSR_FORMAT_VERSION = 1

_COMMENT_PREFIXES = ("#", "%", "//")

Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray]  # (src i64, dst i64, w f32)


# --------------------------------------------------------------------------
# edge-list text parsing (streamed, O(chunk) live objects)
# --------------------------------------------------------------------------

def iter_edgelist_chunks(path: str,
                         chunk_edges: int = DEFAULT_CHUNK_EDGES
                         ) -> Iterator[Chunk]:
    """Stream ``(src, dst, wgt)`` chunks from a whitespace/comma separated
    text edge list. Lines starting with ``#``, ``%`` or ``//`` are comments;
    a third column, when present, is the edge weight (default 1.0)."""
    src, dst, wgt = [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(_COMMENT_PREFIXES):
                continue
            parts = line.replace(",", " ").split()
            src.append(int(parts[0]))
            dst.append(int(parts[1]))
            wgt.append(float(parts[2]) if len(parts) > 2 else 1.0)
            if len(src) >= chunk_edges:
                yield (np.asarray(src, np.int64), np.asarray(dst, np.int64),
                       np.asarray(wgt, np.float32))
                src, dst, wgt = [], [], []
    if src:
        yield (np.asarray(src, np.int64), np.asarray(dst, np.int64),
               np.asarray(wgt, np.float32))


def write_edgelist(path: str, src: np.ndarray, dst: np.ndarray,
                   wgt: Optional[np.ndarray] = None) -> None:
    """Inverse of :func:`iter_edgelist_chunks` (tests / dataset prep)."""
    with open(path, "w") as f:
        f.write("# src dst [wgt]\n")
        if wgt is None:
            for s, d in zip(src, dst):
                f.write(f"{int(s)} {int(d)}\n")
        else:
            for s, d, w in zip(src, dst, wgt):
                f.write(f"{int(s)} {int(d)} {float(w):.8g}\n")


# --------------------------------------------------------------------------
# chunked two-pass CSR builder
# --------------------------------------------------------------------------

def csr_from_chunks(chunks: Callable[[], Iterable[Chunk]],
                    n: Optional[int] = None,
                    undirected: bool = True,
                    dedup: bool = True,
                    block_edges: int = DEFAULT_CHUNK_EDGES) -> CSRGraph:
    """Memory-bounded CSR build from a restartable chunk stream.

    ``chunks`` is a zero-arg callable returning a *fresh* iterator of
    ``(src, dst, wgt)`` arrays each call (the stream is consumed twice).
    Self loops are dropped, ``undirected`` adds reverse edges, ``dedup``
    keeps the **first-arriving** weight per (u, v) in chunk-stream order.
    The resulting CSR is identical to :meth:`CSRGraph.from_edges` except
    when the same undirected edge appears more than once with *conflicting*
    weights: ``from_edges`` orders all forward edges before all reverse
    edges globally, while this builder interleaves them per chunk, so a
    different duplicate may win. Consistent-weight inputs (including all
    unweighted ones) are bit-identical (tested).

    Peak transient allocation is O(n + chunk): pass 1 keeps only the degree
    counts; pass 2 counting-sorts each chunk into the preallocated output
    arrays; the final row-sort/dedup pass streams over row *blocks* of at
    most ``block_edges`` edges and compacts in place (write cursor never
    passes the read cursor).
    """
    # ---- pass 1: degree counts (and n discovery) -------------------------
    counts = np.zeros(1024 if n is None else n, dtype=np.int64)
    n_seen = 0
    for src, dst, _ in chunks():
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if not src.size:
            continue
        hi = int(max(src.max(), dst.max())) + 1
        n_seen = max(n_seen, hi)
        if n is None and hi > counts.shape[0]:
            grown = np.zeros(max(hi, 2 * counts.shape[0]), np.int64)
            grown[:counts.shape[0]] = counts
            counts = grown
        elif n is not None and hi > n:
            raise ValueError(f"vertex id {hi - 1} >= n={n}")
        cb = np.bincount(src)
        counts[:cb.shape[0]] += cb
        if undirected:
            cb = np.bincount(dst)
            counts[:cb.shape[0]] += cb
    if n is None:
        n = n_seen
        counts = counts[:n]
    m_placed = int(counts.sum())

    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    col = np.empty(m_placed, dtype=np.int32)
    wgt = np.empty(m_placed, dtype=np.float32)
    cursor = indptr[:-1].copy()

    # ---- pass 2: counting-sort placement into the preallocated arrays ----
    for src, dst, w in chunks():
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        w = (np.ones(src.shape[0], np.float32) if w is None
             else np.asarray(w, np.float32))
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
        if undirected:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            w = np.concatenate([w, w])
        if not src.size:
            continue
        order = np.argsort(src, kind="stable")
        ss, dd, ww = src[order], dst[order], w[order]
        run_start = np.searchsorted(ss, ss, side="left")
        pos = cursor[ss] + (np.arange(ss.shape[0], dtype=np.int64) - run_start)
        col[pos] = dd
        wgt[pos] = ww
        cb = np.bincount(ss, minlength=n)
        cursor += cb[:n]

    # ---- pass 3: in-place streaming row sort + dedup (block compaction) --
    write = 0
    new_counts = np.zeros(n, dtype=np.int64)
    r0 = 0
    while r0 < n:
        r1 = int(np.searchsorted(indptr, indptr[r0] + block_edges,
                                 side="right"))
        r1 = min(max(r1, r0 + 1), n)  # always >= 1 row, even a huge one
        lo, hi = int(indptr[r0]), int(indptr[r1])
        lens = indptr[r0 + 1:r1 + 1] - indptr[r0:r1]
        rid = np.repeat(np.arange(r1 - r0, dtype=np.int64), lens)
        order = np.lexsort((col[lo:hi], rid))
        c, w_, rs = col[lo:hi][order], wgt[lo:hi][order], rid[order]
        if dedup and c.size:
            first = np.ones(c.shape[0], dtype=bool)
            first[1:] = (c[1:] != c[:-1]) | (rs[1:] != rs[:-1])
            c, w_, rs = c[first], w_[first], rs[first]
        col[write:write + c.shape[0]] = c
        wgt[write:write + c.shape[0]] = w_
        new_counts[r0:r1] = np.bincount(rs, minlength=r1 - r0)
        write += c.shape[0]
        r0 = r1

    np.cumsum(new_counts, out=indptr[1:])
    return CSRGraph(n=n, row_ptr=indptr, col=col[:write], wgt=wgt[:write])


def edgelist_to_csr(path: str, n: Optional[int] = None,
                    undirected: bool = True, dedup: bool = True,
                    chunk_edges: int = DEFAULT_CHUNK_EDGES) -> CSRGraph:
    """Chunked two-pass build of a text edge list (see :func:`csr_from_chunks`)."""
    return csr_from_chunks(
        lambda: iter_edgelist_chunks(path, chunk_edges=chunk_edges),
        n=n, undirected=undirected, dedup=dedup, block_edges=chunk_edges)


# --------------------------------------------------------------------------
# binary CSR disk cache (np.memmap-backed loads)
# --------------------------------------------------------------------------

def save_csr(g: CSRGraph, dirpath: str, graph_version: int = 0) -> str:
    """Write ``g`` as ``{indptr,col,wgt}.npy`` + ``meta.json`` under ``dirpath``.

    ``graph_version`` is the delta counter of the graph being saved (0 for a
    freshly built graph): it rides in ``meta.json`` so a reloaded
    ``GraphStore`` resumes at the right version and so cache keys derived
    from a patched graph never alias the pre-patch entry.
    """
    os.makedirs(dirpath, exist_ok=True)
    np.save(os.path.join(dirpath, "indptr.npy"), g.row_ptr)
    np.save(os.path.join(dirpath, "col.npy"), g.col)
    np.save(os.path.join(dirpath, "wgt.npy"), g.wgt)
    meta = {"version": CSR_FORMAT_VERSION, "n": int(g.n), "m": int(g.m),
            "graph_version": int(graph_version)}
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)
    return dirpath


def csr_meta(dirpath: str) -> dict:
    """The ``meta.json`` of a :func:`save_csr` directory (``graph_version``
    defaults to 0 for caches written before deltas existed)."""
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    meta.setdefault("graph_version", 0)
    return meta


def load_csr(dirpath: str, mmap: bool = True) -> CSRGraph:
    """Load a :func:`save_csr` directory; ``mmap=True`` (default) maps the
    arrays read-only via ``np.memmap`` instead of reading them into RSS."""
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    if meta.get("version") != CSR_FORMAT_VERSION:
        raise ValueError(
            f"CSR cache {dirpath} has version {meta.get('version')}, "
            f"want {CSR_FORMAT_VERSION} — rebuild the cache")
    mode = "r" if mmap else None
    return CSRGraph(
        n=int(meta["n"]),
        row_ptr=np.load(os.path.join(dirpath, "indptr.npy"), mmap_mode=mode),
        col=np.load(os.path.join(dirpath, "col.npy"), mmap_mode=mode),
        wgt=np.load(os.path.join(dirpath, "wgt.npy"), mmap_mode=mode))


# --------------------------------------------------------------------------
# degree-descending relabeling
# --------------------------------------------------------------------------

def relabel_by_degree(g: CSRGraph) -> Tuple[CSRGraph, np.ndarray]:
    """Relabel vertices in descending-degree order (ties: ascending old id).

    Returns ``(relabeled, perm)`` with ``perm[old_id] == new_id``. The
    FN-Cache hot set (``deg > cap``) becomes the contiguous prefix
    ``[0, K)`` and range partitions mix hubs with tail vertices.
    """
    deg = g.deg.astype(np.int64)
    order = np.lexsort((np.arange(g.n), -deg))     # old ids in new-id order
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    lens = deg[order]
    indptr = np.zeros(g.n + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    # segment gather: edges of old row order[i] land in new row i
    idx = (np.repeat(g.row_ptr[order], lens)
           + (np.arange(g.m, dtype=np.int64)
              - np.repeat(indptr[:-1], lens)))
    col = perm[g.col[idx].astype(np.int64)].astype(np.int32)
    wgt = np.asarray(g.wgt)[idx]
    rid = np.repeat(np.arange(g.n, dtype=np.int64), lens)
    o2 = np.lexsort((col, rid))                    # re-sort rows ascending
    return CSRGraph(n=g.n, row_ptr=indptr, col=col[o2], wgt=wgt[o2]), perm


# --------------------------------------------------------------------------
# dataset registry
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Dataset:
    """A loaded graph plus optional sidecar data.

    ``labels`` — per-vertex community labels (``sbm:`` family), indexed by
    the *current* (possibly relabeled) vertex ids. ``perm`` — old→new id
    map when ``relabel=degree`` was applied, else None.
    """
    graph: CSRGraph
    spec: str
    labels: Optional[np.ndarray] = None
    perm: Optional[np.ndarray] = None


_REGISTRY: dict = {}


def register_family(name: str, builder: Callable,
                    keys: Tuple[str, ...] = ()) -> None:
    """Register ``builder(arg, opts) -> CSRGraph | (CSRGraph, labels)`` for
    ``"{name}:..."`` specs. ``arg`` is the positional (path) token, ``opts``
    the parsed ``k=v`` dict (string values). ``keys`` lists the option names
    the builder understands — anything else in a spec is rejected, so a
    typo (``degree=`` for ``deg=``) fails loudly instead of silently
    falling back to a family default."""
    _REGISTRY[name] = (builder, frozenset(keys))


def families() -> tuple:
    return tuple(sorted(_REGISTRY))


def parse_spec(spec: str) -> Tuple[str, Optional[str], dict]:
    """``"family:pos,k=v,..."`` -> (family, pos_or_None, {k: v})."""
    family, _, rest = spec.partition(":")
    family = family.strip()
    if not family:
        raise ValueError(f"empty family in graph spec {spec!r}")
    arg, opts = None, {}
    for tok in rest.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if "=" in tok:
            k, v = tok.split("=", 1)
            opts[k.strip()] = v.strip()
        elif arg is None:
            arg = tok
        else:
            raise ValueError(
                f"graph spec {spec!r} has two positional tokens "
                f"({arg!r}, {tok!r})")
    return family, arg, opts


def _opt(opts: dict, key: str, cast, default=None, required: bool = False):
    if key not in opts:
        if required:
            raise ValueError(f"graph spec option {key!r} is required")
        return default
    return cast(opts[key])


def _flag(opts: dict, key: str, default: bool = False) -> bool:
    v = opts.get(key)
    if v is None:
        return default
    return v.lower() not in ("0", "false", "no", "off")


def _build_er(arg, opts):
    return rmat.er(_opt(opts, "k", int, required=True),
                   avg_degree=_opt(opts, "deg", float, 10.0),
                   seed=_opt(opts, "seed", int, 0))


def _build_wec(arg, opts):
    return rmat.wec(_opt(opts, "k", int, required=True),
                    avg_degree=_opt(opts, "deg", float, 100.0),
                    seed=_opt(opts, "seed", int, 0))


def _build_skew(arg, opts):
    return rmat.skew(_opt(opts, "s", float, required=True),
                     k=_opt(opts, "k", int, 22),
                     avg_degree=_opt(opts, "deg", float, 100.0),
                     seed=_opt(opts, "seed", int, 0))


def _build_rmat(arg, opts):
    return rmat.rmat_graph(_opt(opts, "k", int, required=True),
                           _opt(opts, "deg", float, required=True),
                           _opt(opts, "a", float, 0.25),
                           _opt(opts, "b", float, 0.25),
                           _opt(opts, "c", float, 0.25),
                           _opt(opts, "d", float, 0.25),
                           seed=_opt(opts, "seed", int, 0))


def _build_sbm(arg, opts):
    return rmat.sbm_labeled(_opt(opts, "n", int, required=True),
                            _opt(opts, "c", int, required=True),
                            _opt(opts, "pin", float, required=True),
                            _opt(opts, "pout", float, required=True),
                            seed=_opt(opts, "seed", int, 0))


def _build_edgelist(arg, opts):
    if arg is None:
        raise ValueError("edgelist spec needs a path: 'edgelist:/path.txt'")
    return edgelist_to_csr(
        arg, n=_opt(opts, "n", int),
        undirected=not _flag(opts, "directed"),
        dedup=_flag(opts, "dedup", True),
        chunk_edges=_opt(opts, "chunk", int, DEFAULT_CHUNK_EDGES))


def _build_csr_dir(arg, opts):
    if arg is None:
        raise ValueError("csr spec needs a directory: 'csr:/path/dir'")
    return load_csr(arg, mmap=_flag(opts, "mmap", True))


for _name, _fn, _keys in [
        ("er", _build_er, ("k", "deg", "seed")),
        ("wec", _build_wec, ("k", "deg", "seed")),
        ("skew", _build_skew, ("s", "k", "deg", "seed")),
        ("rmat", _build_rmat, ("k", "deg", "a", "b", "c", "d", "seed")),
        ("sbm", _build_sbm, ("n", "c", "pin", "pout", "seed")),
        ("edgelist", _build_edgelist, ("n", "directed", "dedup", "chunk")),
        ("csr", _build_csr_dir, ("mmap",))]:
    register_family(_name, _fn, _keys)

_COMMON_OPTS = frozenset(("relabel",))


def _edgelist_cache_key(path: str, opts: dict, graph_version: int = 0) -> str:
    # relabel is part of the key: the cached artifact is the *final* graph.
    # graph_version is the delta counter: a patched graph (version > 0) must
    # never alias the cache entry of its pre-patch ancestor, whose mtime and
    # size it may share exactly (in-place splices conserve both).
    st = os.stat(path)
    tag = (f"{os.path.abspath(path)}|{st.st_mtime_ns}|{st.st_size}|"
           f"v{CSR_FORMAT_VERSION}|gv{int(graph_version)}|"
           f"{sorted(opts.items())}")
    return hashlib.sha1(tag.encode()).hexdigest()[:12]


def _load_dataset(spec: str, cache_dir: Optional[str] = None) -> Dataset:
    """Resolve a graph spec string to a :class:`Dataset`.

    Internal (non-deprecated) implementation behind
    ``repro.data.open_graph``; the public ``load_dataset``/``load_graph``
    names are thin deprecated shims over it.

    ``cache_dir`` (edgelist family only): the chunked build — including any
    ``relabel=degree`` pass — runs once, is written as a binary CSR cache
    keyed on (path, mtime, size, options, graph version), and every later
    load is ``np.memmap``-backed from that cache (the relabel ``perm`` is
    cached alongside as ``perm.npy``).
    """
    family, arg, opts = parse_spec(spec)
    if family not in _REGISTRY:
        raise ValueError(
            f"unknown graph family {family!r} (have {families()}); spec was "
            f"{spec!r}")
    builder, known_keys = _REGISTRY[family]
    unknown = set(opts) - known_keys - _COMMON_OPTS
    if unknown:
        raise ValueError(
            f"unknown option(s) {sorted(unknown)} for graph family "
            f"{family!r} (known: {sorted(known_keys | _COMMON_OPTS)}); "
            f"spec was {spec!r}")
    relabel = opts.get("relabel")
    if relabel not in (None, "degree", "1", "true"):
        raise ValueError(f"unknown relabel option {relabel!r} (want 'degree')")

    if family == "edgelist" and cache_dir is not None:
        if arg is None:
            raise ValueError(
                "edgelist spec needs a path: 'edgelist:/path.txt'")
        key = _edgelist_cache_key(arg, opts)
        sub = os.path.join(cache_dir, f"{os.path.basename(arg)}-{key}")
        perm_path = os.path.join(sub, "perm.npy")
        if not os.path.exists(os.path.join(sub, "meta.json")):
            g = builder(arg, opts)
            perm = None
            if relabel is not None:
                g, perm = relabel_by_degree(g)
            # build into a temp dir and rename into place, so a concurrent
            # loader never memmaps a partially written cache
            tmp = f"{sub}.tmp{os.getpid()}"
            save_csr(g, tmp)
            if perm is not None:
                np.save(os.path.join(tmp, "perm.npy"), perm)
            try:
                os.rename(tmp, sub)
            except OSError:                     # another process won
                shutil.rmtree(tmp, ignore_errors=True)
        g = load_csr(sub)                       # memmap-backed
        perm = np.load(perm_path, mmap_mode="r") \
            if os.path.exists(perm_path) else None
        return Dataset(graph=g, spec=spec, labels=None, perm=perm)

    out = builder(arg, opts)
    g, labels = out if isinstance(out, tuple) else (out, None)
    perm = None
    if relabel is not None:
        g, perm = relabel_by_degree(g)
        if labels is not None:
            order = np.argsort(perm)            # new id -> old id
            labels = np.asarray(labels)[order]
    return Dataset(graph=g, spec=spec, labels=labels, perm=perm)


def load_dataset(spec: str, cache_dir: Optional[str] = None) -> Dataset:
    """DEPRECATED shim — use ``repro.data.open_graph(spec)``; the returned
    :class:`~repro.data.store.GraphStore` exposes ``.graph``, ``.labels``,
    ``.perm`` and adds the versioned ``.apply(deltas)`` update path."""
    from repro.core.walk import warn_deprecated_once
    warn_deprecated_once("load_dataset", api="repro.data.open_graph(spec)")
    return _load_dataset(spec, cache_dir=cache_dir)


def load_graph(spec: str, cache_dir: Optional[str] = None) -> CSRGraph:
    """DEPRECATED shim — use ``repro.data.open_graph(spec).graph`` (see
    module docstring for the spec grammar)."""
    from repro.core.walk import warn_deprecated_once
    warn_deprecated_once("load_graph", api="repro.data.open_graph(spec)")
    return _load_dataset(spec, cache_dir=cache_dir).graph
