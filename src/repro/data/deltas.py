"""Versioned edge-delta batches + the shard-local CSR patch (DESIGN.md §15).

Real social graphs mutate under traffic; the paper's Pregel model assumes a
static resident graph. This module is the ingestion half of the incremental
update path: a validated, per-shard-sorted batch format
(:class:`DeltaBatch`), the CSR patch that applies one
(:func:`apply_delta_csr` — in-place per-shard splice when the edge count is
conserved, shard-local rebuild otherwise, never a whole-graph rebuild), and
a Zipf churn-stream generator for the update benchmarks
(:func:`zipf_churn`). The device half — invalidating only the affected
shards' alias tables and hot-set entries — lives in ``repro.engine.update``.

Semantics of one batch, applied atomically:

1. **Removals first**: each ``(u, v)`` in the remove list is deleted if
   present; removals of absent edges are counted (``removed_missing``) but
   are not errors — churn streams race with themselves.
2. **Upserts second**: each ``(u, v, w)`` in the add list *replaces* the
   weight of an existing edge or inserts a new one. An edge both removed
   and re-added in the same batch ends up present with the new weight.

Batches are **directed** internally; :meth:`DeltaBatch.build` symmetrizes
undirected input (the CSR convention everywhere else in the repo), drops
self loops, dedups (last occurrence wins — the freshest event), and sorts
by ``(src, dst)``, which is *per-shard sorted* for any range partition of
vertex ids — the property the per-shard patch kernel relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.core.graph import CSRGraph

DELTA_FORMAT_VERSION = 1


def _as_ids(x) -> np.ndarray:
    a = np.atleast_1d(np.asarray(x, np.int64))
    if a.ndim != 1:
        raise ValueError(f"edge endpoint arrays must be 1-D, got {a.shape}")
    return a


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One validated, normalized batch of edge additions/removals.

    All arrays are sorted by ``(src, dst)`` and duplicate-free; adds carry
    per-edge weights. ``base_version`` optionally pins the batch to the
    :class:`~repro.data.store.GraphStore` version it was generated against —
    ``GraphStore.apply`` rejects the batch if the store has moved on.
    """
    add_src: np.ndarray                  # [A] int64
    add_dst: np.ndarray                  # [A] int64
    add_wgt: np.ndarray                  # [A] float32, > 0
    rem_src: np.ndarray                  # [R] int64
    rem_dst: np.ndarray                  # [R] int64
    base_version: Optional[int] = None

    @property
    def num_add(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def num_remove(self) -> int:
        return int(self.rem_src.shape[0])

    @property
    def num_edges(self) -> int:
        """Total directed delta edges carried by the batch."""
        return self.num_add + self.num_remove

    @staticmethod
    def build(add: Optional[Tuple] = None, remove: Optional[Tuple] = None,
              undirected: bool = True,
              base_version: Optional[int] = None) -> "DeltaBatch":
        """Normalize raw edge lists into a :class:`DeltaBatch`.

        ``add`` is ``(src, dst)`` or ``(src, dst, wgt)`` (default weight
        1.0); ``remove`` is ``(src, dst)``. Self loops are dropped;
        ``undirected`` (default, matching the CSR builders) adds reverse
        edges before dedup; on duplicate ``(u, v)`` the **last** occurrence
        wins (the freshest churn event).
        """
        def norm(pair, with_w):
            if pair is None:
                s = np.zeros(0, np.int64)
                return (s, s.copy(), np.zeros(0, np.float32)) if with_w \
                    else (s, s.copy())
            if with_w and len(pair) == 3:
                s, d, w = pair
                w = np.broadcast_to(
                    np.asarray(w, np.float32), _as_ids(s).shape).copy()
            else:
                s, d = pair[0], pair[1]
                w = None
            s, d = _as_ids(s), _as_ids(d)
            if s.shape != d.shape:
                raise ValueError(
                    f"src/dst length mismatch: {s.shape} vs {d.shape}")
            if with_w:
                if w is None:
                    w = np.ones(s.shape[0], np.float32)
                return s, d, w
            return s, d

        a_s, a_d, a_w = norm(add, with_w=True)
        r_s, r_d = norm(remove, with_w=False)
        if a_w.size and not (np.isfinite(a_w).all() and (a_w > 0).all()):
            raise ValueError("edge weights must be finite and > 0")

        keep = a_s != a_d
        a_s, a_d, a_w = a_s[keep], a_d[keep], a_w[keep]
        keep = r_s != r_d
        r_s, r_d = r_s[keep], r_d[keep]
        if undirected:
            a_s, a_d = np.concatenate([a_s, a_d]), np.concatenate([a_d, a_s])
            a_w = np.concatenate([a_w, a_w])
            r_s, r_d = np.concatenate([r_s, r_d]), np.concatenate([r_d, r_s])

        def sort_dedup(s, d, w=None):
            order = np.lexsort((d, s))
            s, d = s[order], d[order]
            if w is not None:
                w = w[order]
            if s.size:
                # keep the LAST duplicate (stable lexsort preserves arrival
                # order within equal keys)
                last = np.ones(s.shape[0], bool)
                last[:-1] = (s[1:] != s[:-1]) | (d[1:] != d[:-1])
                s, d = s[last], d[last]
                if w is not None:
                    w = w[last]
            return (s, d, w) if w is not None else (s, d)

        a_s, a_d, a_w = sort_dedup(a_s, a_d, a_w)
        r_s, r_d = sort_dedup(r_s, r_d)
        return DeltaBatch(add_src=a_s, add_dst=a_d, add_wgt=a_w,
                          rem_src=r_s, rem_dst=r_d,
                          base_version=base_version)

    def check(self, n: int) -> None:
        """Validate endpoints against a graph of ``n`` vertices. Deltas are
        edge-only: they never grow the vertex set."""
        for name, a in (("add_src", self.add_src), ("add_dst", self.add_dst),
                        ("rem_src", self.rem_src), ("rem_dst", self.rem_dst)):
            if a.size and (int(a.min()) < 0 or int(a.max()) >= n):
                bad = int(a[(a < 0) | (a >= n)][0])
                raise ValueError(
                    f"{name} contains vertex id {bad} outside [0, {n})")

    def remap(self, perm: np.ndarray) -> "DeltaBatch":
        """Map endpoint ids through ``perm[old_id] == new_id`` (the
        ``relabel=degree`` permutation frozen at ``open_graph`` time).

        Re-sorts after mapping: a permutation preserves dedup but not the
        ``(src, dst)`` order the per-shard patch kernel slices by."""
        p = np.asarray(perm, np.int64)
        a_s, a_d = p[self.add_src], p[self.add_dst]
        r_s, r_d = p[self.rem_src], p[self.rem_dst]
        ao = np.lexsort((a_d, a_s))
        ro = np.lexsort((r_d, r_s))
        return DeltaBatch(
            add_src=a_s[ao], add_dst=a_d[ao], add_wgt=self.add_wgt[ao],
            rem_src=r_s[ro], rem_dst=r_d[ro],
            base_version=self.base_version)


# --------------------------------------------------------------------------
# shard-local CSR patch
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PatchReport:
    """Accounting for one applied batch (or an aggregate of several).

    ``affected`` / ``affected_shards`` identify exactly the rows and
    range-partition shards whose adjacency changed — the engine's device
    invalidation is driven off these. ``in_place`` reports whether the
    splice reused the existing ``col``/``wgt`` arrays (possible when every
    affected shard's edge count is conserved and the arrays are writable).
    """
    num_shards: int
    n_local: int
    affected: np.ndarray           # [A] int64, sorted unique vertex ids
    affected_shards: np.ndarray    # [S_a] int64, sorted unique shard ids
    edges_added: int
    edges_removed: int
    edges_updated: int
    removed_missing: int
    in_place: bool
    m_before: int
    m_after: int

    @property
    def num_affected(self) -> int:
        return int(self.affected.shape[0])

    @property
    def delta_edges(self) -> int:
        return self.edges_added + self.edges_removed + self.edges_updated

    @property
    def shard_fraction(self) -> float:
        """Fraction of range-partition shards invalidated by the batch."""
        return len(self.affected_shards) / max(self.num_shards, 1)

    def merge(self, other: "PatchReport") -> "PatchReport":
        """Aggregate sequentially applied reports (same partition)."""
        return PatchReport(
            num_shards=self.num_shards, n_local=self.n_local,
            affected=np.union1d(self.affected, other.affected),
            affected_shards=np.union1d(self.affected_shards,
                                       other.affected_shards),
            edges_added=self.edges_added + other.edges_added,
            edges_removed=self.edges_removed + other.edges_removed,
            edges_updated=self.edges_updated + other.edges_updated,
            removed_missing=self.removed_missing + other.removed_missing,
            in_place=self.in_place and other.in_place,
            m_before=self.m_before, m_after=other.m_after)


def _patch_segment(g: CSRGraph, lo_v: int, hi_v: int,
                   rem_s, rem_d, add_s, add_d, add_w):
    """Recompute one shard's CSR segment under its slice of the batch.

    Works on the globally sorted key ``row * n + col`` (rows are sorted
    ascending in CSR, so the segment key is sorted): removals and upsert
    lookups are vectorized ``searchsorted`` probes, inserts are a merge.
    Returns (col, wgt, per-row lens, removed, missing, updated, added).
    """
    n = g.n
    lo_e, hi_e = int(g.row_ptr[lo_v]), int(g.row_ptr[hi_v])
    seg_col = np.asarray(g.col[lo_e:hi_e], np.int64)
    seg_wgt = np.array(g.wgt[lo_e:hi_e], np.float32)   # copy: weights mutate
    lens = (np.asarray(g.row_ptr[lo_v + 1:hi_v + 1])
            - np.asarray(g.row_ptr[lo_v:hi_v]))
    rid = np.repeat(np.arange(lo_v, hi_v, dtype=np.int64), lens)
    key = rid * n + seg_col

    def probe(qkey):
        if not key.size:
            return np.zeros(qkey.shape[0], np.int64), \
                np.zeros(qkey.shape[0], bool)
        pos = np.searchsorted(key, qkey)
        safe = np.minimum(pos, key.shape[0] - 1)
        return safe, (pos < key.shape[0]) & (key[safe] == qkey)

    keep = np.ones(key.shape[0], bool)
    removed = missing = 0
    if rem_s.size:
        pos, found = probe(rem_s * n + rem_d)
        keep[pos[found]] = False
        removed, missing = int(found.sum()), int((~found).sum())

    updated = added = 0
    ins_key = np.zeros(0, np.int64)
    ins_w = np.zeros(0, np.float32)
    if add_s.size:
        akey = add_s * n + add_d
        pos, exists = probe(akey)
        upd = exists & keep[pos]           # removed-and-re-added -> insert
        seg_wgt[pos[upd]] = add_w[upd]
        updated = int(upd.sum())
        ins = ~upd
        ins_key, ins_w = akey[ins], add_w[ins]
        added = int(ins.sum())

    new_key = np.concatenate([key[keep], ins_key])
    new_w = np.concatenate([seg_wgt[keep], ins_w])
    order = np.argsort(new_key, kind="stable")
    new_key, new_w = new_key[order], new_w[order]
    new_lens = np.bincount(new_key // n - lo_v,
                           minlength=hi_v - lo_v).astype(np.int64)
    return (new_key % n).astype(np.int32), new_w, new_lens, \
        removed, missing, updated, added


def apply_delta_csr(g: CSRGraph, batch: DeltaBatch, num_shards: int = 64,
                    allow_in_place: bool = True
                    ) -> Tuple[CSRGraph, PatchReport]:
    """Apply one :class:`DeltaBatch` to a host CSR graph, shard-locally.

    The vertex range is partitioned into ``num_shards`` contiguous shards
    (``shard(v) = v // ceil(n / num_shards)`` — the same range partition
    ``ShardedGraph`` uses). Only shards containing a delta endpoint's *row*
    are recomputed; every other shard's segment is untouched (in-place) or
    copied wholesale (rebuild). When every affected shard conserves its edge
    count (pure weight updates, or adds balancing removals per shard) and
    the arrays are writable (not read-only memmaps), the patch splices in
    place with zero reallocation; otherwise new ``col``/``wgt`` arrays are
    allocated and unaffected segments are block-copied — never a whole-graph
    re-sort.
    """
    batch.check(g.n)
    n = g.n
    num_shards = max(1, min(int(num_shards), max(n, 1)))
    n_local = -(-n // num_shards) if n else 1
    affected = np.unique(np.concatenate([batch.add_src, batch.rem_src]))
    shards = np.unique(affected // n_local).astype(np.int64)
    m_before = g.m
    if not affected.size:
        report = PatchReport(
            num_shards=num_shards, n_local=n_local, affected=affected,
            affected_shards=shards, edges_added=0, edges_removed=0,
            edges_updated=0, removed_missing=0, in_place=True,
            m_before=m_before, m_after=m_before)
        return g, report

    def shard_slice(arr_s, arr_d, lo_v, hi_v, *extra):
        lo = np.searchsorted(arr_s, lo_v, side="left")
        hi = np.searchsorted(arr_s, hi_v, side="left")
        out = [arr_s[lo:hi], arr_d[lo:hi]]
        out.extend(e[lo:hi] for e in extra)
        return out

    patched = {}
    removed = missing = updated = added = 0
    conserved = True
    for s in shards.tolist():
        lo_v, hi_v = s * n_local, min((s + 1) * n_local, n)
        r_s, r_d = shard_slice(batch.rem_src, batch.rem_dst, lo_v, hi_v)
        a_s, a_d, a_w = shard_slice(batch.add_src, batch.add_dst, lo_v, hi_v,
                                    batch.add_wgt)
        col_s, wgt_s, lens_s, rm, ms, up, ad = _patch_segment(
            g, lo_v, hi_v, r_s, r_d, a_s, a_d, a_w)
        patched[s] = (lo_v, hi_v, col_s, wgt_s, lens_s)
        removed += rm
        missing += ms
        updated += up
        added += ad
        old_len = int(g.row_ptr[hi_v] - g.row_ptr[lo_v])
        conserved = conserved and col_s.shape[0] == old_len

    writable = (getattr(g.col, "flags", None) is not None
                and g.col.flags.writeable and g.wgt.flags.writeable
                and g.row_ptr.flags.writeable)
    in_place = allow_in_place and conserved and writable
    if in_place:
        for lo_v, hi_v, col_s, wgt_s, lens_s in patched.values():
            lo_e = int(g.row_ptr[lo_v])
            g.col[lo_e:lo_e + col_s.shape[0]] = col_s
            g.wgt[lo_e:lo_e + wgt_s.shape[0]] = wgt_s
            # only intra-shard row boundaries move; shard ends are conserved
            g.row_ptr[lo_v + 1:hi_v] = lo_e + np.cumsum(lens_s)[:-1]
        out = g
        m_after = m_before
    else:
        lens_all = (np.asarray(g.row_ptr[1:])
                    - np.asarray(g.row_ptr[:-1])).astype(np.int64)
        for lo_v, hi_v, _, _, lens_s in patched.values():
            lens_all[lo_v:hi_v] = lens_s
        row_ptr = np.zeros(n + 1, np.int64)
        np.cumsum(lens_all, out=row_ptr[1:])
        m_after = int(row_ptr[-1])
        col = np.empty(m_after, np.int32)
        wgt = np.empty(m_after, np.float32)
        for s in range(num_shards):
            lo_v, hi_v = s * n_local, min((s + 1) * n_local, n)
            if hi_v <= lo_v:
                break
            dst_lo = int(row_ptr[lo_v])
            if s in patched:
                _, _, col_s, wgt_s, _ = patched[s]
                col[dst_lo:dst_lo + col_s.shape[0]] = col_s
                wgt[dst_lo:dst_lo + wgt_s.shape[0]] = wgt_s
            else:                           # block copy, no per-row work
                src_lo, src_hi = int(g.row_ptr[lo_v]), int(g.row_ptr[hi_v])
                col[dst_lo:dst_lo + (src_hi - src_lo)] = g.col[src_lo:src_hi]
                wgt[dst_lo:dst_lo + (src_hi - src_lo)] = g.wgt[src_lo:src_hi]
        out = CSRGraph(n=n, row_ptr=row_ptr, col=col, wgt=wgt)

    report = PatchReport(
        num_shards=num_shards, n_local=n_local, affected=affected,
        affected_shards=shards, edges_added=added, edges_removed=removed,
        edges_updated=updated, removed_missing=missing, in_place=in_place,
        m_before=m_before, m_after=m_after)
    return out, report


# --------------------------------------------------------------------------
# Zipf churn stream (bench/test workload)
# --------------------------------------------------------------------------

def weight_churn(g: CSRGraph, num_batches: int, batch_edges: int,
                 alpha: float = 1.0, seed: int = 0,
                 top: Optional[int] = None) -> Iterator[DeltaBatch]:
    """Weight-only churn: re-weight existing edges whose endpoints both sit
    in the ``top`` highest-degree vertices (Zipf(``alpha``) over the source's
    degree rank). Degrees never change, so these batches always take the
    no-relayout device path and the in-place CSR splice — the steady-state
    "interaction intensities drift" workload the update benchmark gates."""
    rng = np.random.default_rng(seed)
    rank = np.argsort(-g.deg.astype(np.int64), kind="stable")  # rank -> id
    k_cand = g.n if top is None else max(2, min(int(top), g.n))
    cand = rank[:k_cand]
    in_cand = np.zeros(g.n, bool)
    in_cand[cand] = True
    src_rank = np.full(g.n, k_cand, np.int64)
    src_rank[cand] = np.arange(k_cand)

    lens = (np.asarray(g.row_ptr[1:]) - np.asarray(g.row_ptr[:-1]))
    rid = np.repeat(np.arange(g.n, dtype=np.int64), lens)
    col = np.asarray(g.col, np.int64)
    live = (rid < col) & in_cand[rid] & in_cand[col]   # each edge once
    e_src, e_dst = rid[live], col[live]
    if not e_src.size:
        raise ValueError(f"no edges with both endpoints in the top {k_cand}")
    probs = 1.0 / (src_rank[e_src] + 1).astype(np.float64) ** alpha
    probs /= probs.sum()

    for _ in range(num_batches):
        k = min(batch_edges, e_src.shape[0])
        idx = rng.choice(e_src.shape[0], size=k, replace=False, p=probs)
        w = rng.uniform(0.5, 2.0, size=k).astype(np.float32)
        yield DeltaBatch.build(add=(e_src[idx], e_dst[idx], w))


def zipf_churn(g: CSRGraph, num_batches: int, batch_edges: int,
               alpha: float = 1.0, seed: int = 0,
               add_fraction: float = 0.5,
               weight_updates: bool = True,
               top: Optional[int] = None) -> Iterator[DeltaBatch]:
    """Generate ``num_batches`` valid churn batches against ``g``.

    Endpoints are drawn Zipf(``alpha``) over *degree rank* — under
    ``relabel=degree`` that is Zipf over vertex id, so churn concentrates on
    the low-id shards exactly like serving traffic does. ``top`` truncates
    the candidate set to the ``top`` highest-degree vertices (both endpoints
    of every event), which bounds the set of shards a batch can touch — the
    update benchmark uses this to pin the invalidated-shard fraction. Each
    batch holds ``batch_edges`` undirected events split between additions
    (new edges or, with ``weight_updates``, weight bumps on existing ones)
    and removals of currently-present edges between candidate vertices; the
    stream tracks its own edits so removals target live edges and re-adds
    are well defined.
    """
    rng = np.random.default_rng(seed)
    rank = np.argsort(-g.deg.astype(np.int64), kind="stable")  # rank -> id
    k_cand = g.n if top is None else max(2, min(int(top), g.n))
    cand = rank[:k_cand]
    in_cand = np.zeros(g.n, bool)
    in_cand[cand] = True
    probs = 1.0 / np.arange(1, k_cand + 1, dtype=np.float64) ** alpha
    probs /= probs.sum()

    # live edges with BOTH endpoints in the candidate set — the removal pool
    live = set()
    for u in cand.tolist():
        for v in g.neighbors(u):
            v = int(v)
            if u < v and in_cand[v]:
                live.add((u, v))

    def draw(k):
        return cand[rng.choice(k_cand, size=k, p=probs)]

    for _ in range(num_batches):
        n_add = int(round(batch_edges * add_fraction))
        n_rem = batch_edges - n_add
        adds = []
        while len(adds) < n_add:
            us, vs = draw(n_add), draw(n_add)
            for u, v in zip(us.tolist(), vs.tolist()):
                if u == v or len(adds) >= n_add:
                    continue
                e = (min(u, v), max(u, v))
                if e in live and not weight_updates:
                    continue
                adds.append((u, v, float(rng.uniform(0.5, 2.0))))
                live.add(e)
        rems = []
        pool = sorted(live)
        if pool and n_rem:
            idx = rng.permutation(len(pool))[:n_rem]
            for i in idx.tolist():
                e = pool[i]
                rems.append(e)
                live.discard(e)
        add_arr = np.asarray(adds, np.float64).reshape(-1, 3)
        rem_arr = np.asarray(rems, np.int64).reshape(-1, 2)
        yield DeltaBatch.build(
            add=(add_arr[:, 0].astype(np.int64),
                 add_arr[:, 1].astype(np.int64),
                 add_arr[:, 2].astype(np.float32)),
            remove=(rem_arr[:, 0], rem_arr[:, 1]))
