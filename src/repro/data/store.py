"""GraphStore — the unified, versioned graph handle (DESIGN.md §15).

``open_graph`` collapses the old ``load_graph``/``load_dataset`` duality
into one entry point::

    store = open_graph("rmat:k=13,deg=16,seed=0,relabel=degree")
    store.graph            # host CSRGraph (the current version)
    store.version          # delta counter, 0 at open
    store.meta             # {"spec", "n", "m", "version", ...}
    store.apply(deltas)    # patch in a DeltaBatch -> PatchReport, version += 1

``open_graph`` accepts a spec string, a raw :class:`CSRGraph`, a
:class:`~repro.data.ingest.Dataset`, or an existing :class:`GraphStore`
(passthrough) — ``WalkEngine.build`` and ``serve.EmbeddingService`` take
any of these uniformly and hold the store so their ``update``/``refresh``
paths can track churn.

Id-space contract under ``relabel=degree``: deltas are expressed in the
**original** (pre-relabel) vertex ids and mapped through the permutation
frozen at open time — so ``open_graph(spec)`` followed by the same delta
sequence is a well-defined, reproducible graph state regardless of when the
relabel happened (the property tests rebuild exactly this way). The
permutation is *never* recomputed after deltas: degree churn does not move
vertices between shards mid-run (bounded staleness; reopen to re-rank).
"""
from __future__ import annotations

import os
from typing import Iterable, Optional, Union

import numpy as np

from repro.core.graph import CSRGraph
from repro.data.deltas import DeltaBatch, PatchReport, apply_delta_csr
from repro.data.ingest import (Dataset, _load_dataset, csr_meta, parse_spec,
                               save_csr)

DEFAULT_PATCH_SHARDS = 64


class GraphStore:
    """A mutable, versioned handle over one resident host graph.

    ``version`` counts applied :class:`DeltaBatch` es (each batch is one
    atomic version bump). ``num_shards`` is the *patch* granularity — the
    range partition :func:`~repro.data.deltas.apply_delta_csr` localizes
    work (and invalidation accounting) to; it is independent of the device
    mesh, which re-derives its own shard map from the patched CSR.
    """

    def __init__(self, dataset: Dataset, *,
                 num_shards: int = DEFAULT_PATCH_SHARDS,
                 version: int = 0) -> None:
        self._graph = dataset.graph
        self.spec = dataset.spec
        self.labels = dataset.labels
        self.perm = None if dataset.perm is None \
            else np.asarray(dataset.perm, np.int64)
        self.num_shards = max(1, int(num_shards))
        self.version = int(version)
        self.last_report: Optional[PatchReport] = None

    # ---------------------------------------------------------- accessors --
    @property
    def graph(self) -> CSRGraph:
        """The current-version host CSR graph."""
        return self._graph

    @property
    def meta(self) -> dict:
        return {
            "spec": self.spec,
            "n": int(self._graph.n),
            "m": int(self._graph.m),
            "version": self.version,
            "num_shards": self.num_shards,
            "relabeled": self.perm is not None,
            "has_labels": self.labels is not None,
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"GraphStore(spec={self.spec!r}, n={self._graph.n}, "
                f"m={self._graph.m}, version={self.version})")

    # ------------------------------------------------------------- update --
    def apply(self, deltas: Union[DeltaBatch, Iterable[DeltaBatch]]
              ) -> PatchReport:
        """Apply one batch (or a sequence, each a version bump) and return
        the (aggregated) :class:`~repro.data.deltas.PatchReport`.

        Batches carrying ``base_version`` are rejected unless it matches the
        store's current version — a stale producer cannot silently clobber a
        newer graph. Delta ids are original-space; see the module docstring.
        """
        batches = [deltas] if isinstance(deltas, DeltaBatch) else list(deltas)
        if not batches:
            raise ValueError("apply() needs at least one DeltaBatch")
        report = None
        for batch in batches:
            if not isinstance(batch, DeltaBatch):
                raise TypeError(
                    f"expected DeltaBatch, got {type(batch).__name__} — "
                    f"build one with DeltaBatch.build(add=..., remove=...)")
            if batch.base_version is not None \
                    and batch.base_version != self.version:
                raise ValueError(
                    f"stale delta batch: built against version "
                    f"{batch.base_version}, store is at {self.version}")
            mapped = batch if self.perm is None else batch.remap(self.perm)
            self._graph, rep = apply_delta_csr(
                self._graph, mapped, num_shards=self.num_shards)
            self.version += 1
            report = rep if report is None else report.merge(rep)
        self.last_report = report
        return report

    # --------------------------------------------------------------- save --
    def save(self, dirpath: str) -> str:
        """Persist the current version as a ``csr:`` directory (graph +
        version + perm/labels sidecars); ``open_graph(f"csr:{dirpath}")``
        restores the store at the same version."""
        save_csr(self._graph, dirpath, graph_version=self.version)
        if self.perm is not None:
            np.save(os.path.join(dirpath, "perm.npy"), self.perm)
        if self.labels is not None:
            np.save(os.path.join(dirpath, "labels.npy"),
                    np.asarray(self.labels))
        return dirpath


def open_graph(source, cache_dir: Optional[str] = None, *,
               num_shards: int = DEFAULT_PATCH_SHARDS) -> GraphStore:
    """Open any graph source as a :class:`GraphStore`.

    ``source`` may be a spec string (``"wec:k=10,deg=30"``,
    ``"edgelist:/path.txt"``, ``"csr:/cache/dir"`` — the
    ``repro.data.ingest`` grammar), a host :class:`CSRGraph`, a
    :class:`~repro.data.ingest.Dataset`, or an existing store (returned
    as-is, so APIs can accept "anything graph-like" and normalize through
    this one call). ``cache_dir`` is forwarded to the edgelist builder.
    """
    if isinstance(source, GraphStore):
        return source
    if isinstance(source, Dataset):
        return GraphStore(source, num_shards=num_shards)
    if isinstance(source, CSRGraph):
        return GraphStore(Dataset(graph=source, spec="<CSRGraph>"),
                          num_shards=num_shards)
    if not isinstance(source, str):
        raise TypeError(
            f"open_graph wants a spec string, CSRGraph, Dataset, or "
            f"GraphStore; got {type(source).__name__}")
    ds = _load_dataset(source, cache_dir=cache_dir)
    version = 0
    family, arg, _ = parse_spec(source)
    if family == "csr" and arg is not None:
        version = int(csr_meta(arg).get("graph_version", 0))
        if ds.perm is None:
            perm_path = os.path.join(arg, "perm.npy")
            if os.path.exists(perm_path):
                ds = Dataset(graph=ds.graph, spec=ds.spec, labels=ds.labels,
                             perm=np.load(perm_path))
        if ds.labels is None:
            lab_path = os.path.join(arg, "labels.npy")
            if os.path.exists(lab_path):
                ds = Dataset(graph=ds.graph, spec=ds.spec,
                             labels=np.load(lab_path), perm=ds.perm)
    return GraphStore(ds, num_shards=num_shards, version=version)
