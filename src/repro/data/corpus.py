"""Walk corpus -> training examples.

Two consumers:

* SGNS (Node2Vec stage 2): sliding-window (center, context) pairs + unigram^
  0.75 negative sampling — ``walks_to_sgns_batches``.
* LM architectures: walks are token sequences over the vertex vocabulary
  (DeepWalk-style corpus); ``walks_to_lm_tokens`` packs them into fixed-length
  model inputs so any assigned architecture can train on graph data.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core.alias import build_alias


def sgns_pairs(walks: np.ndarray, window: int) -> Tuple[np.ndarray, np.ndarray]:
    """All (center, context) pairs within +-window along each walk.

    walks: [W, L] int32 (self-loop tails from dead-end walks are kept —
    they are rare and harmless, matching the reference implementation).
    """
    w, l = walks.shape
    centers, contexts = [], []
    for off in range(1, window + 1):
        if off >= l:
            break
        a = walks[:, :-off].reshape(-1)
        b = walks[:, off:].reshape(-1)
        centers.append(a)
        contexts.append(b)
        centers.append(b)
        contexts.append(a)
    c = np.concatenate(centers) if centers else np.zeros(0, np.int32)
    x = np.concatenate(contexts) if contexts else np.zeros(0, np.int32)
    keep = c != x
    return c[keep].astype(np.int32), x[keep].astype(np.int32)


class NegativeSampler:
    """Unigram^0.75 negative sampler over the walk corpus (word2vec's choice),
    via the same Vose alias machinery as the walk engine."""

    def __init__(self, walks: np.ndarray, vocab: int, power: float = 0.75):
        counts = np.bincount(walks.reshape(-1), minlength=vocab).astype(
            np.float64)
        freq = counts ** power
        if freq.sum() == 0:
            freq = np.ones(vocab)
        self.prob, self.alias = build_alias(freq)
        self.vocab = vocab

    def sample(self, rng: np.random.Generator, shape) -> np.ndarray:
        slots = rng.integers(0, self.vocab, size=shape)
        u = rng.random(shape)
        take = u >= self.prob[slots]
        return np.where(take, self.alias[slots], slots).astype(np.int32)


def walks_to_sgns_batches(walks: np.ndarray, vocab: int, window: int,
                          negatives: int, batch_size: int, seed: int = 0,
                          epochs: int = 1) -> Iterator[dict]:
    """Yield padded, shuffled SGNS batches: center/pos [B], neg [B, K],
    valid [B] (last batch is padded)."""
    centers, contexts = sgns_pairs(walks, window)
    sampler = NegativeSampler(walks, vocab)
    rng = np.random.default_rng(seed)
    n = centers.shape[0]
    for _ in range(epochs):
        perm = rng.permutation(n)
        for lo in range(0, n, batch_size):
            idx = perm[lo:lo + batch_size]
            b = idx.shape[0]
            pad = batch_size - b
            c = np.pad(centers[idx], (0, pad))
            p = np.pad(contexts[idx], (0, pad))
            # negatives only for the b live rows: padded tails (valid == 0)
            # contribute nothing to the loss, so drawing for them just burns
            # rng + alias lookups
            neg = np.zeros((batch_size, negatives), np.int32)
            if b:
                neg[:b] = sampler.sample(rng, (b, negatives))
            valid = np.pad(np.ones(b, np.float32), (0, pad))
            yield {"center": c, "pos": p, "neg": neg, "valid": valid}


def walks_to_lm_tokens(walks: np.ndarray, seq_len: int,
                       bos: int | None = None) -> np.ndarray:
    """Pack walk corpus into [N, seq_len] LM training sequences (token ids are
    vertex ids; optional BOS separates walks)."""
    rows = []
    if bos is not None:
        w, l = walks.shape
        stream = np.concatenate(
            [np.full((w, 1), bos, walks.dtype), walks], axis=1).reshape(-1)
    else:
        stream = walks.reshape(-1)
    n = stream.shape[0] // seq_len
    return stream[:n * seq_len].reshape(n, seq_len).astype(np.int32)
