"""repro.data — graph ingestion, the versioned GraphStore handle, and the
edge-delta update path (DESIGN.md §10, §15).

Public surface::

    from repro.data import open_graph, GraphStore, DeltaBatch

    store = open_graph("rmat:k=13,deg=16,relabel=degree")
    store.apply(DeltaBatch.build(add=([0, 1], [5, 9])))   # version += 1

``load_graph``/``load_dataset`` remain as deprecated shims over
``open_graph`` (one-shot ``DeprecationWarning``). Submodules: ``ingest``
(spec registry + CSR builders/cache), ``deltas`` (batch format + CSR
patch), ``store`` (GraphStore/open_graph), ``corpus``/``pipeline``
(walk-corpus tooling).
"""
from __future__ import annotations

_EXPORTS = {
    "open_graph": "repro.data.store",
    "GraphStore": "repro.data.store",
    "DeltaBatch": "repro.data.deltas",
    "PatchReport": "repro.data.deltas",
    "apply_delta_csr": "repro.data.deltas",
    "zipf_churn": "repro.data.deltas",
    "Dataset": "repro.data.ingest",
    "load_graph": "repro.data.ingest",
    "load_dataset": "repro.data.ingest",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    # lazy: importing repro.data must not pull jax before submodules need it
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.data' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
