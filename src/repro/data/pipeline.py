"""Host-side data pipeline: sharded batch iterator with background prefetch.

Production posture: each host process feeds only its addressable slice of the
global batch (``jax.make_array_from_process_local_data`` handles multi-host);
a background thread keeps ``prefetch`` batches ready so host data work
overlaps device compute (one of the paper-era systems lessons we keep:
overlap I/O with compute — GraphLite does the same with its message lists).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class PrefetchIterator:
    """Wrap a host iterator with a daemon prefetch thread."""

    def __init__(self, it: Iterator, prefetch: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._done = object()
        self._err: Optional[BaseException] = None

        def worker():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # surfaced on next()
                self._err = e
            finally:
                self._q.put(self._done)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def shard_batches(it: Iterator[dict], mesh: Mesh, batch_axes=("data",),
                  prefetch: int = 2) -> Iterator[dict]:
    """Device-put host batches with the leading axis sharded over
    ``batch_axes`` of ``mesh``; prefetches in the background."""
    spec = P(batch_axes)

    def put(batch):
        out = {}
        for k, v in batch.items():
            v = np.asarray(v)
            pspec = spec if v.ndim >= 1 else P()
            out[k] = jax.device_put(v, NamedSharding(mesh, pspec))
        return out

    return PrefetchIterator((put(b) for b in it), prefetch=prefetch)
