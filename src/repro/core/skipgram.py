"""Skip-gram with negative sampling (SGNS) — Node2Vec stage 2.

The paper focuses on the walk stage (98.8% of Spark runtime) but a complete
system needs the optimization stage too: this is the standard word2vec SGNS
objective [Mikolov'13] applied to walk corpora [Grover & Leskovec'16]:

    L = -log sigma(u_c . v_p) - sum_k log sigma(-u_c . v_nk)

Embedding tables are sharded over the ``model`` mesh axis on the vocab
(vertex) dimension so billion-vertex graphs scale: each device holds V/TP
rows; gathers/scatter-grads lower to collectives under pjit.

The fused forward/backward inner product is also available as a Pallas TPU
kernel (``repro.kernels.sgns``); this module is the pure-jnp reference path
used for CPU tests and as the kernel oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import Optimizer, apply_updates


@dataclasses.dataclass(frozen=True)
class SGNSConfig:
    vocab: int
    dim: int = 128
    negatives: int = 5
    param_dtype: Any = jnp.float32


def init_params(cfg: SGNSConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    k1, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(cfg.dim)
    return {
        "emb_in": (jax.random.uniform(k1, (cfg.vocab, cfg.dim),
                                      cfg.param_dtype) - 0.5) * 2 * scale,
        "emb_out": jnp.zeros((cfg.vocab, cfg.dim), cfg.param_dtype),
    }


def log_sigmoid(x):
    return -jnp.logaddexp(0.0, -x)


def sgns_loss(params, center: jnp.ndarray, pos: jnp.ndarray,
              negs: jnp.ndarray, valid: Optional[jnp.ndarray] = None):
    """Batch SGNS loss. center/pos: [B]; negs: [B, K]; valid: [B] mask."""
    ci = params["emb_in"][center]            # [B, D]
    po = params["emb_out"][pos]              # [B, D]
    no = params["emb_out"][negs]             # [B, K, D]
    pos_score = jnp.sum(ci * po, axis=-1)
    neg_score = jnp.einsum("bd,bkd->bk", ci, no)
    per = -(log_sigmoid(pos_score) + jnp.sum(log_sigmoid(-neg_score), -1))
    if valid is None:
        return jnp.mean(per)
    denom = jnp.maximum(jnp.sum(valid), 1.0)
    return jnp.sum(per * valid) / denom


SGNS_BACKENDS = ("jnp", "fused")


def sgns_grads(params, batch, backend: str = "jnp"):
    """Loss + parameter gradients for one SGNS batch.

    ``backend="jnp"``   — autodiff through the gathered-rows loss (reference).
    ``backend="fused"`` — gather rows, run the Pallas fused loss+grad kernel
    (``repro.kernels.sgns``: ci/po/no read once, three grads written once),
    scatter-add the row grads back into the tables. Same math as autodiff
    (the kernel-vs-autodiff contract is tested in tests/test_kernels.py);
    interpret mode off-TPU.
    """
    center, pos, negs = batch["center"], batch["pos"], batch["neg"]
    valid = batch.get("valid")
    if backend == "jnp":
        def loss_fn(p):
            return sgns_loss(p, center, pos, negs, valid)

        return jax.value_and_grad(loss_fn)(params)
    if backend != "fused":
        raise ValueError(
            f"sgns backend must be one of {SGNS_BACKENDS}, got {backend!r}")
    from repro.kernels.ops import sgns_fused_op
    v = jnp.ones(center.shape[0], jnp.float32) if valid is None else \
        valid.astype(jnp.float32)
    ci = params["emb_in"][center]
    po = params["emb_out"][pos]
    no = params["emb_out"][negs]
    loss_sum, g_ci, g_po, g_no = sgns_fused_op(ci, po, no, v)
    # the kernel returns the masked *sum*; the jnp path trains on the masked
    # mean — scale by the same denominator so both backends see one gradient
    denom = jnp.maximum(jnp.sum(v), 1.0)
    g_in = jnp.zeros_like(params["emb_in"]).at[center].add(g_ci / denom)
    g_out = (jnp.zeros_like(params["emb_out"])
             .at[pos].add(g_po / denom)
             .at[negs].add(g_no / denom))
    return loss_sum / denom, {"emb_in": g_in, "emb_out": g_out}


@functools.partial(jax.jit, static_argnames=("opt", "backend"),
                   donate_argnums=(0, 1))
def train_step(params, opt_state, batch, opt: Optimizer,
               backend: str = "jnp"):
    loss, grads = sgns_grads(params, batch, backend)
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates)
    return params, opt_state, loss


def normalize_embeddings(params) -> jnp.ndarray:
    e = params["emb_in"].astype(jnp.float32)
    return e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-8)


def serving_table(params) -> np.ndarray:
    """The train->serve handoff: host f32 unit-norm ``[V, D]`` table in the
    layout ``repro.serve.EmbeddingService`` holds resident. One call site
    owns the normalization convention, so trainer and server cannot drift
    (the service also accepts a raw SGNS params dict and calls this)."""
    return np.asarray(jax.device_get(normalize_embeddings(params)))
