"""Distributed Fast-Node2Vec walk engine (shard_map over the device mesh) —
the ``"sharded"`` backend of ``repro.engine.WalkEngine``.

Pregel -> TPU-SPMD mapping (see DESIGN.md §2):

* One Pregel **superstep** == one iteration of a ``lax.scan``; the BSP barrier
  is the collective itself.
* The graph is **range-partitioned** by vertex id across the flattened mesh
  axis ``rw`` (all devices of the production mesh). Walkers are co-located
  with their start vertex, so the paper's STEP messages (sampled step sent
  back to the start vertex) become *local buffer writes* — zero traffic.
* The paper's NEIG message (neighbor list of the current vertex) becomes a
  **pull**: a two-phase ``all_to_all`` — request ids out, neighbor rows back.
  - FN-Local: the diagonal block of the all_to_all never crosses ICI, and
    fully-local requests skip the exchange entirely.
  - FN-Cache: rows of every vertex with degree > cap are replicated in the
    hot cache, so popular vertices never enter the exchange and the payload
    width is the *cold* cap, not the max degree. This is the statically
    visible collective-bytes reduction measured in the roofline.
  - FN-Approx: at a hot v reached from a cold u, if the Eq. 2-3 gap < eps the
    step is an O(1) alias draw from the replicated table — no wide prob row.
* The NEIG payload for the *next* step's dist(u, x) test is the row we just
  fetched — carried in walker state (Algorithm 1 line 22), cold width only;
  hot prev rows are re-read from the replicated cache at compute time.

All sampling math (exact inverse-CDF draw, approx gating, alias fast path)
lives in ``repro.engine.sampler`` and is shared verbatim with the reference
and fused backends; this module only owns the *layout*: partitioning, the
request/response exchange, and the candidate-row assembly.

RNG keys are ``fold_in(seed, global_walker_id, step)`` — identical to the
single-device reference, so distributed walks are **bit-identical** to
the reference backend (validated in tests).

Capacity: the request exchange has a static per-destination capacity ``C``
*per exchange*. Requests beyond C are *dropped* (walker stays put for that
step) and counted in the returned diagnostics (surfaced as
``WalkStats.dropped``); exact-mode callers size C so drops are zero (tests
assert this). The paper's FN-Multi (walker rounds) is the production lever
for bounding C — see ``runtime/fault_tolerance.py``.

Async superstep pipeline (``WalkPlan.pipeline``, DESIGN.md §12): walkers on
each shard split into two fixed cohorts (A = first ceil(W/2) local rows).
The barrier loop's issue-exchange/compute halves are re-interleaved so
cohort B's step-k NEIG exchange is on the wire while cohort A's walkers
advance through step k, and A's step-(k+1) exchange issues before B's
step-k compute — each collective hides behind the other cohort's sampling
work. Cohorts never read each other's state and per-(walker, step) RNG keys
are layout-independent, so pipelined walks are **bit-identical** to barrier
walks (tested). The last superstep is peeled out of the scan so no dangling
exchange is issued past the end of the walk. Cohort exchanges carry half
the walkers, so the zero-drop capacity default also halves — per-superstep
total bytes stay at the barrier level, split across two overlapped
messages.

The ``distributed_walks`` shim (deprecated in PR 7) was removed in PR 9;
all callers go through ``repro.engine.WalkEngine`` (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import PAD_ID, PaddedGraph
from repro.core.walk import WalkParams, walker_key
from repro.engine.sampler import HotContext, Sampler, first_order_slots

RW_AXIS = "rw"


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-portable shard_map: jax.shard_map (new) falls back to
    jax.experimental.shard_map (0.4.x); the replication-check kwarg was
    renamed check_rep -> check_vma along the way, so gate on the signature."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    kwargs = {}
    params = inspect.signature(sm).parameters
    for flag in ("check_vma", "check_rep"):
        if flag in params:
            kwargs[flag] = False
            break
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["adj", "wgt", "alias_p", "alias_i", "deg", "hot_ids",
                 "hot_adj", "hot_wgt", "hot_alias_p", "hot_alias_i",
                 "hot_deg", "hot_wmin", "hot_wmax"],
    meta_fields=["n", "n_orig", "num_shards", "cap", "hot_cap"])
@dataclasses.dataclass
class ShardedGraph:
    """Host-built container of device-ready arrays for the sharded engine.

    Row-sharded over ``rw``: adj, wgt, alias_p, alias_i, deg.
    Replicated: hot arrays + per-hot-vertex scalars.
    """
    n: int            # padded vertex count (multiple of num_shards)
    n_orig: int
    num_shards: int
    cap: int
    hot_cap: int
    adj: jnp.ndarray          # [n, cap]
    wgt: jnp.ndarray          # [n, cap]
    alias_p: jnp.ndarray      # [n, cap]
    alias_i: jnp.ndarray      # [n, cap]
    deg: jnp.ndarray          # [n]
    hot_ids: jnp.ndarray      # [K] sorted ascending
    hot_adj: jnp.ndarray      # [K, hot_cap]
    hot_wgt: jnp.ndarray      # [K, hot_cap]
    hot_alias_p: jnp.ndarray  # [K, hot_cap]
    hot_alias_i: jnp.ndarray  # [K, hot_cap]
    hot_deg: jnp.ndarray      # [K]
    hot_wmin: jnp.ndarray     # [K]
    hot_wmax: jnp.ndarray     # [K]

    @property
    def n_local(self) -> int:
        return self.n // self.num_shards

    def hot_pack(self) -> tuple:
        return (self.hot_ids, self.hot_adj, self.hot_wgt, self.hot_alias_p,
                self.hot_alias_i, self.hot_deg, self.hot_wmin, self.hot_wmax)

    @staticmethod
    def from_csr(g, num_shards: int, cap: Optional[int] = None,
                 hot_cap: Optional[int] = None) -> "ShardedGraph":
        """Shard-by-shard build straight from a host :class:`CSRGraph`.

        Packs each shard's padded rows (and alias tables) directly from CSR
        slices into the preallocated output arrays — no dense whole-graph
        :class:`PaddedGraph` intermediate (that path materializes a second
        full [n, cap] copy plus per-vertex scalars the sharded engine never
        reads). Bit-identical to
        ``ShardedGraph.build(PaddedGraph.build(g, cap, hot_cap), n)``
        (asserted in tests), including the no-hot sentinel row.
        """
        from repro.core.alias import build_alias_rows

        deg = g.deg                                   # [n] i32
        max_deg = g.max_degree
        if cap is None or cap >= max(max_deg, 1):
            cap = max(max_deg, 1)
        cap = max(int(cap), 1)
        hot_vertices = np.nonzero(deg > cap)[0].astype(np.int32)
        if hot_cap is None:
            hot_cap = int(deg[hot_vertices].max()) if len(hot_vertices) \
                else cap
        hot_cap = max(int(hot_cap), cap)
        n = g.n
        n_pad = ((n + num_shards - 1) // num_shards) * num_shards
        n_local = n_pad // num_shards

        def pack_block(vertices, out_adj, out_wgt):
            width = out_adj.shape[1]
            for i, v in enumerate(vertices):
                lo, hi = g.row_ptr[v], g.row_ptr[v + 1]
                d = min(int(hi - lo), width)
                out_adj[i, :d] = g.col[lo:lo + d]
                out_wgt[i, :d] = g.wgt[lo:lo + d]

        adj = np.full((n_pad, cap), PAD_ID, np.int32)
        wgt = np.zeros((n_pad, cap), np.float32)
        alias_p = np.zeros((n_pad, cap), np.float32)
        alias_i = np.zeros((n_pad, cap), np.int32)
        alias_p[n:] = 1.0                   # padding rows: build()'s pad fill
        deg_pad = np.zeros(n_pad, np.int32)
        deg_pad[:n] = deg
        for s in range(num_shards):
            lo_v, hi_v = s * n_local, min((s + 1) * n_local, n)
            if hi_v <= lo_v:
                break
            pack_block(range(lo_v, hi_v), adj[lo_v:hi_v], wgt[lo_v:hi_v])
            ap, ai = build_alias_rows(wgt[lo_v:hi_v])
            alias_p[lo_v:hi_v] = ap
            alias_i[lo_v:hi_v] = ai

        def row_min_max(v, width):
            lo = g.row_ptr[v]
            d = min(int(g.row_ptr[v + 1] - lo), width)
            if d == 0:
                return 1.0, 1.0
            w = g.wgt[lo:lo + d]
            return float(w.min()), float(w.max())

        if len(hot_vertices):
            k = len(hot_vertices)
            hot_ids = hot_vertices
            hot_adj = np.full((k, hot_cap), PAD_ID, np.int32)
            hot_wgt = np.zeros((k, hot_cap), np.float32)
            pack_block(hot_vertices, hot_adj, hot_wgt)
            hot_deg = deg[hot_vertices]
            mm = np.array([row_min_max(int(v), hot_cap)
                           for v in hot_vertices], np.float32)
            hot_wmin, hot_wmax = mm[:, 0], mm[:, 1]
        else:
            # sentinel row; the scalar lanes mirror build()'s clamped
            # pg.deg[PAD_ID] / w_min[PAD_ID] gathers (last real vertex)
            hot_ids = np.full(1, PAD_ID, np.int32)
            hot_adj = np.full((1, hot_cap), PAD_ID, np.int32)
            hot_wgt = np.zeros((1, hot_cap), np.float32)
            hot_deg = deg[n - 1:n]
            wmin, wmax = row_min_max(n - 1, cap)
            hot_wmin = np.full(1, wmin, np.float32)
            hot_wmax = np.full(1, wmax, np.float32)
        hot_alias_p, hot_alias_i = build_alias_rows(hot_wgt)

        return ShardedGraph(
            n=n_pad, n_orig=n, num_shards=num_shards, cap=cap,
            hot_cap=hot_cap,
            adj=jnp.asarray(adj), wgt=jnp.asarray(wgt),
            alias_p=jnp.asarray(alias_p), alias_i=jnp.asarray(alias_i),
            deg=jnp.asarray(deg_pad),
            hot_ids=jnp.asarray(hot_ids), hot_adj=jnp.asarray(hot_adj),
            hot_wgt=jnp.asarray(hot_wgt),
            hot_alias_p=jnp.asarray(hot_alias_p),
            hot_alias_i=jnp.asarray(hot_alias_i),
            hot_deg=jnp.asarray(hot_deg),
            hot_wmin=jnp.asarray(hot_wmin),
            hot_wmax=jnp.asarray(hot_wmax))

    @staticmethod
    def build(pg: PaddedGraph, num_shards: int) -> "ShardedGraph":
        n_pad = ((pg.n + num_shards - 1) // num_shards) * num_shards

        def pad_rows(x, fill):
            if n_pad == pg.n:
                return x
            pad = jnp.full((n_pad - pg.n,) + x.shape[1:], fill, x.dtype)
            return jnp.concatenate([x, pad], axis=0)

        hot_deg = pg.deg[pg.hot_ids]
        hot_wmin = pg.w_min[pg.hot_ids]
        hot_wmax = pg.w_max[pg.hot_ids]
        return ShardedGraph(
            n=n_pad, n_orig=pg.n, num_shards=num_shards, cap=pg.cap,
            hot_cap=pg.hot_cap,
            adj=pad_rows(pg.adj, PAD_ID), wgt=pad_rows(pg.wgt, 0.0),
            alias_p=pad_rows(pg.alias_p, 1.0),
            alias_i=pad_rows(pg.alias_i, 0),
            deg=pad_rows(pg.deg, 0),
            hot_ids=pg.hot_ids, hot_adj=pg.hot_adj, hot_wgt=pg.hot_wgt,
            hot_alias_p=pg.hot_alias_p, hot_alias_i=pg.hot_alias_i,
            hot_deg=hot_deg, hot_wmin=hot_wmin, hot_wmax=hot_wmax)


def _hot_lookup(hot_ids: jnp.ndarray, v: jnp.ndarray):
    """Replicated hot-set membership: (is_hot, position)."""
    k = hot_ids.shape[0]
    pos = jnp.minimum(jnp.searchsorted(hot_ids, v), k - 1)
    return hot_ids[pos] == v, pos


def _bucket_requests(dest: jnp.ndarray, needs_remote: jnp.ndarray,
                     v: jnp.ndarray, num_shards: int, capacity: int):
    """Pack remote requests into per-destination slots of width ``capacity``.

    Returns (buf [S*C] request ids, slot_of_walker [W] (-1 if none), dropped
    mask [W]). Deterministic: walkers are ranked by (dest, walker order).
    """
    w = dest.shape[0]
    sort_key = jnp.where(needs_remote, dest, num_shards)
    order = jnp.argsort(sort_key, stable=True)
    sorted_key = sort_key[order]
    first = jnp.searchsorted(sorted_key, sorted_key, side="left")
    rank_sorted = jnp.arange(w, dtype=jnp.int32) - first.astype(jnp.int32)
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    ok = needs_remote & (rank < capacity)
    size = num_shards * capacity
    # slot==size is a scratch lane for every non-request; sliced off below.
    slot = jnp.where(ok, dest * capacity + rank, size)
    buf = jnp.full((size + 1,), PAD_ID, jnp.int32)
    buf = buf.at[slot].set(v)[:size]
    slot = jnp.where(ok, slot, -1)
    dropped = needs_remote & ~ok
    return buf, slot, dropped


def _serve_requests(g: ShardedGraph, adj, wgt, recv_ids: jnp.ndarray,
                    shard_offset: jnp.ndarray):
    """Gather local rows for incoming request ids [R]. PAD_ID -> pad row."""
    local = jnp.clip(recv_ids - shard_offset, 0, adj.shape[0] - 1)
    valid = recv_ids != PAD_ID
    ids = jnp.where(valid[:, None], adj[local], PAD_ID)
    w = jnp.where(valid[:, None], wgt[local], 0.0)
    return ids, w


def _widen(x: jnp.ndarray, width: int, fill) -> jnp.ndarray:
    d = x.shape[-1]
    if d >= width:
        return x
    pad = jnp.full(x.shape[:-1] + (width - d,), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=-1)


def _issue_exchange(g: ShardedGraph, adj, wgt, v, capacity: int):
    """Issue the two-phase NEIG pull for a walker cohort at positions ``v``.

    This is the *communication half* of a superstep: bucket the remote
    requests, all_to_all the ids out, gather the local rows for incoming
    requests, and all_to_all the rows back. It depends only on ``v`` (and
    the graph), never on the sampling state, so the pipelined walk body can
    issue one cohort's exchange before (= overlapped with) the other
    cohort's compute. Returns the exchange state consumed by
    ``_finish_step``: (resp_i [S*C, cap], resp_w, slot [Wc], dropped [Wc]).
    """
    num_shards = g.num_shards
    n_local = adj.shape[0]
    my_shard = jax.lax.axis_index(RW_AXIS)
    shard_offset = my_shard.astype(jnp.int32) * n_local

    is_hot_v, _ = _hot_lookup(g.hot_ids, v)
    dest = (v // n_local).astype(jnp.int32)
    is_local = dest == my_shard
    needs_remote = (~is_hot_v) & (~is_local)

    buf, slot, dropped = _bucket_requests(dest, needs_remote, v, num_shards,
                                          capacity)
    req = buf.reshape(num_shards, capacity)
    recv = jax.lax.all_to_all(req, RW_AXIS, split_axis=0, concat_axis=0,
                              tiled=True)
    rows_i, rows_w = _serve_requests(g, adj, wgt, recv.reshape(-1),
                                     shard_offset)
    rows_i = rows_i.reshape(num_shards, capacity, g.cap)
    rows_w = rows_w.reshape(num_shards, capacity, g.cap)
    resp_i = jax.lax.all_to_all(rows_i, RW_AXIS, 0, 0, tiled=True)
    resp_w = jax.lax.all_to_all(rows_w, RW_AXIS, 0, 0, tiled=True)
    resp_i = resp_i.reshape(num_shards * capacity, g.cap)
    resp_w = resp_w.reshape(num_shards * capacity, g.cap)
    return resp_i, resp_w, slot, dropped


def _finish_step(g: ShardedGraph, adj, wgt, u, v, prev_ids, prev_deg, step,
                 seed_key, walker_ids, sampler: Sampler, exchange):
    """Compute half of a superstep: candidate assembly + the 2nd-order draw,
    given the already-exchanged NEIG responses for this cohort."""
    resp_i, resp_w, slot, dropped = exchange
    n_local = adj.shape[0]
    my_shard = jax.lax.axis_index(RW_AXIS)
    shard_offset = my_shard.astype(jnp.int32) * n_local
    is_hot_v, hot_pos_v = _hot_lookup(g.hot_ids, v)

    # --- assemble candidate rows per walker (local / remote / hot) ---
    v_local_idx = jnp.clip(v - shard_offset, 0, n_local - 1)
    local_i, local_w = adj[v_local_idx], wgt[v_local_idx]
    safe_slot = jnp.maximum(slot, 0)
    remote_i, remote_w = resp_i[safe_slot], resp_w[safe_slot]
    use_remote = slot >= 0
    cold_i = jnp.where(use_remote[:, None], remote_i, local_i)
    cold_w = jnp.where(use_remote[:, None], remote_w, local_w)
    hp = jnp.maximum(hot_pos_v, 0)
    if sampler.mode == "approx_always":
        # beyond-paper FN-Approx: popular vertices ALWAYS take the O(1)
        # alias path, so the exact-prob pass runs at cold width only and the
        # [W, hot_cap] candidate assembly disappears entirely (static shapes
        # otherwise evaluate both branches — see EXPERIMENTS.md §Perf).
        cand_i = _widen(cold_i, g.cap, PAD_ID)
        cand_w = _widen(cold_w, g.cap, 0.0)
    else:
        cand_i = jnp.where(is_hot_v[:, None], g.hot_adj[hp],
                           _widen(cold_i, g.hot_cap, PAD_ID))
        cand_w = jnp.where(is_hot_v[:, None], g.hot_wgt[hp],
                           _widen(cold_w, g.hot_cap, 0.0))

    # --- previous row for dist(u, x): carried if cold, cache if hot ---
    is_hot_u, hot_pos_u = _hot_lookup(g.hot_ids, u)
    hpu = jnp.maximum(hot_pos_u, 0)
    prev_row = jnp.where(is_hot_u[:, None], g.hot_adj[hpu],
                         _widen(prev_ids, g.hot_cap, PAD_ID))
    deg_u = jnp.where(is_hot_u, g.hot_deg[hpu], prev_deg)

    # --- 2nd-order sampling: the shared Sampler (same math, all backends) ---
    keys = jax.vmap(lambda i: walker_key(seed_key, i, step))(walker_ids)
    hot = None
    if sampler.mode != "exact":
        hot = HotContext(
            is_hot_v=is_hot_v, is_hot_u=is_hot_u,
            deg_u=deg_u, deg_v=g.hot_deg[hp],
            w_min_v=g.hot_wmin[hp], w_max_v=g.hot_wmax[hp],
            alias_p=g.hot_alias_p[hp], alias_i=g.hot_alias_i[hp],
            alias_deg=g.hot_deg[hp])
    choice = sampler.choose(keys, cand_i, cand_w, u, prev_row, hot)
    if sampler.mode == "approx_always":
        # candidates stayed at cold width: hot next-ids come straight from
        # the replicated cache ([W] gather, O(1)/walker)
        nxt_hot = g.hot_adj[hp, choice.slot_alias]
        nxt_cold = jnp.take_along_axis(cand_i, choice.slot_exact[:, None],
                                       axis=1)[:, 0]
        nxt = jnp.where(choice.use_alias, nxt_hot, nxt_cold)
    else:
        nxt = jnp.take_along_axis(cand_i, choice.slot()[:, None],
                                  axis=1)[:, 0]
    deg_v = jnp.sum(cand_w > 0, axis=1).astype(jnp.int32)
    if sampler.mode == "approx_always":
        deg_v = jnp.where(is_hot_v, g.hot_deg[hp], deg_v)
    alive = (deg_v > 0) & ~dropped
    nxt = jnp.where(alive, nxt, v)

    # carried NEIG payload for the next step (cold width)
    new_prev_ids = jnp.where(is_hot_v[:, None], PAD_ID, cold_i)
    return nxt, new_prev_ids, deg_v, dropped


def _sharded_step(g: ShardedGraph, adj, wgt, alias_p, alias_i, deg,
                  u, v, prev_ids, prev_deg, step, seed_key, walker_ids,
                  sampler: Sampler, capacity: int):
    """One barrier superstep for the local walker block: exchange, then
    compute — the two halves back-to-back (runs inside shard_map)."""
    exchange = _issue_exchange(g, adj, wgt, v, capacity)
    return _finish_step(g, adj, wgt, u, v, prev_ids, prev_deg, step,
                        seed_key, walker_ids, sampler, exchange)


def _first_step_local(g: ShardedGraph, adj, wgt, alias_p, alias_i, deg,
                      starts, seed_key, walker_ids):
    """Step 0: starts are local by construction; 1st-order alias draw."""
    my_shard = jax.lax.axis_index(RW_AXIS)
    n_local = adj.shape[0]
    off = my_shard.astype(jnp.int32) * n_local
    li = jnp.clip(starts - off, 0, n_local - 1)
    is_hot, hp = _hot_lookup(g.hot_ids, starts)
    hp = jnp.maximum(hp, 0)
    ap = jnp.where(is_hot[:, None], g.hot_alias_p[hp],
                   _widen(alias_p[li], g.hot_cap, 0.0))
    ai = jnp.where(is_hot[:, None], g.hot_alias_i[hp],
                   _widen(alias_i[li], g.hot_cap, 0))
    ids = jnp.where(is_hot[:, None], g.hot_adj[hp],
                    _widen(adj[li], g.hot_cap, PAD_ID))
    keys = jax.vmap(lambda i: walker_key(seed_key, i, 0))(walker_ids)
    slots = first_order_slots(keys, ap, ai, deg[li])
    nxt = jnp.take_along_axis(ids, slots[:, None], axis=1)[:, 0]
    nxt = jnp.where(deg[li] > 0, nxt, starts)
    prev_ids = adj[li]
    prev_deg = deg[li]
    return nxt, prev_ids, prev_deg


def make_distributed_walk(g: ShardedGraph, mesh: Mesh, params: WalkParams,
                          capacity: int, length: Optional[int] = None,
                          pipeline: bool = False):
    """Build the jitted distributed walk fn over ``mesh`` (all axes flattened
    into the ``rw`` axis via an abstract mesh reshape is the caller's job —
    this function expects a 1-D mesh with axis name 'rw').

    ``pipeline=True`` selects the double-buffered async-superstep body: the
    local walker block is split into two independent cohorts (A = first
    ceil(W/2) rows, B = the rest; walks are per-walker so any split is
    legal), and each cohort's NEIG exchange is issued in program order
    *before* the other cohort's compute — on hardware with async collectives
    the exchange hides behind the sampling work (DESIGN.md §12). ``capacity``
    is per destination *per exchange* in both modes; because a cohort is a
    subset of the block, a walker's within-cohort request rank never exceeds
    its barrier-mode rank, so pipelined drops are a subset of barrier drops
    at equal capacity (and both are zero at the engine's defaults). Walks
    are bit-identical to the barrier body (tested).
    """
    length = length or params.length
    sampler = params.sampler() if isinstance(params, WalkParams) else params
    pspec_rows = P(RW_AXIS)
    rep = P()

    def make_local(hot_pack):
        return dataclasses.replace(
            g, hot_ids=hot_pack[0], hot_adj=hot_pack[1], hot_wgt=hot_pack[2],
            hot_alias_p=hot_pack[3], hot_alias_i=hot_pack[4],
            hot_deg=hot_pack[5], hot_wmin=hot_pack[6], hot_wmax=hot_pack[7])

    def walk_body(adj, wgt, alias_p, alias_i, deg, hot_pack, starts,
                  walker_ids, seed_key):
        gl = make_local(hot_pack)
        v1, prev_ids, prev_deg = _first_step_local(
            gl, adj, wgt, alias_p, alias_i, deg, starts, seed_key, walker_ids)

        def body(carry, s):
            u, v, p_ids, p_deg, drops = carry
            nxt, np_ids, deg_v, dropped = _sharded_step(
                gl, adj, wgt, alias_p, alias_i, deg, u, v, p_ids, p_deg, s,
                seed_key, walker_ids, sampler, capacity)
            drops = drops + jnp.sum(dropped.astype(jnp.int32))
            return (v, nxt, np_ids, deg_v, drops), v

        init = (starts, v1, prev_ids, prev_deg, jnp.zeros((), jnp.int32))
        (_, v_last, _, _, drops), steps = jax.lax.scan(
            body, init, jnp.arange(1, length, dtype=jnp.int32))
        walks = jnp.concatenate([steps.T, v_last[:, None]], axis=1)
        return walks, jax.lax.psum(drops, RW_AXIS)

    def walk_body_pipelined(adj, wgt, alias_p, alias_i, deg, hot_pack,
                            starts, walker_ids, seed_key):
        gl = make_local(hot_pack)
        w_local = starts.shape[0]
        wa = (w_local + 1) // 2          # cohort A size (static)
        v1, prev_ids, prev_deg = _first_step_local(
            gl, adj, wgt, alias_p, alias_i, deg, starts, seed_key, walker_ids)

        def split(x):
            return x[:wa], x[wa:]

        u_a, u_b = split(starts)
        v_a, v_b = split(v1)
        p_a, p_b = split(prev_ids)
        pd_a, pd_b = split(prev_deg)
        wid_a, wid_b = split(walker_ids)

        def finish(u, v, p_ids, p_deg, wids, s, exch):
            return _finish_step(gl, adj, wgt, u, v, p_ids, p_deg, s,
                                seed_key, wids, sampler, exch)

        # pipeline prologue: A's step-1 exchange (nothing to hide behind)
        exch_a = _issue_exchange(gl, adj, wgt, v_a, capacity)

        def body(carry, s):
            (u_a, v_a, p_a, pd_a, u_b, v_b, p_b, pd_b, exch_a, drops) = carry
            # B's step-s exchange: issued BEFORE A's compute — overlaps it
            exch_b = _issue_exchange(gl, adj, wgt, v_b, capacity)
            nxt_a, np_a, deg_a, drop_a = finish(u_a, v_a, p_a, pd_a, wid_a,
                                                s, exch_a)
            # A's step-(s+1) exchange: issued BEFORE B's compute
            exch_a = _issue_exchange(gl, adj, wgt, nxt_a, capacity)
            nxt_b, np_b, deg_b, drop_b = finish(u_b, v_b, p_b, pd_b, wid_b,
                                                s, exch_b)
            drops = drops + jnp.sum(drop_a.astype(jnp.int32)) \
                + jnp.sum(drop_b.astype(jnp.int32))
            emit = jnp.concatenate([v_a, v_b])
            return (v_a, nxt_a, np_a, deg_a, v_b, nxt_b, np_b, deg_b,
                    exch_a, drops), emit

        init = (u_a, v_a, p_a, pd_a, u_b, v_b, p_b, pd_b, exch_a,
                jnp.zeros((), jnp.int32))
        # peel the last superstep so no dangling prefetch is ever issued
        carry, steps = jax.lax.scan(
            body, init, jnp.arange(1, length - 1, dtype=jnp.int32))
        (u_a, v_a, p_a, pd_a, u_b, v_b, p_b, pd_b, exch_a, drops) = carry
        s_last = jnp.asarray(length - 1, jnp.int32)
        exch_b = _issue_exchange(gl, adj, wgt, v_b, capacity)
        nxt_a, _, _, drop_a = finish(u_a, v_a, p_a, pd_a, wid_a, s_last,
                                     exch_a)
        nxt_b, _, _, drop_b = finish(u_b, v_b, p_b, pd_b, wid_b, s_last,
                                     exch_b)
        drops = drops + jnp.sum(drop_a.astype(jnp.int32)) \
            + jnp.sum(drop_b.astype(jnp.int32))
        v_prev = jnp.concatenate([v_a, v_b])
        v_last = jnp.concatenate([nxt_a, nxt_b])
        walks = jnp.concatenate(
            [steps.T, v_prev[:, None], v_last[:, None]], axis=1) \
            if length > 2 else jnp.concatenate(
                [v_prev[:, None], v_last[:, None]], axis=1)
        return walks, jax.lax.psum(drops, RW_AXIS)

    # length 1 has no exchanging supersteps — nothing to pipeline
    body_fn = walk_body_pipelined if pipeline and length >= 2 else walk_body
    shard_fn = _shard_map(
        body_fn, mesh=mesh,
        in_specs=(pspec_rows, pspec_rows, pspec_rows, pspec_rows, pspec_rows,
                  rep, pspec_rows, pspec_rows, rep),
        out_specs=(pspec_rows, rep))
    return jax.jit(shard_fn)
