"""End-to-end Node2Vec driver: graph -> Fast-Node2Vec walks -> SGNS embeddings.

This composes the paper's two stages as a first-class framework feature. The
walk stage runs r rounds (paper: r walks per vertex == FN-Multi rounds), each
round being a checkpoint / elastic-rescale boundary; rounds overlap with SGNS
training on the previous round's corpus (compute/"communication" overlap at
the pipeline level).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.graph import CSRGraph, PaddedGraph
from repro.core.skipgram import (SGNSConfig, init_params, normalize_embeddings,
                                 train_step)
from repro.core.walk import WalkParams, simulate_walks
from repro.core.walk_distributed import distributed_walks
from repro.data.corpus import walks_to_sgns_batches
from repro.optim.optimizers import adam


@dataclasses.dataclass
class Node2VecConfig:
    p: float = 1.0
    q: float = 1.0
    walk_length: int = 80
    num_walks: int = 10           # r: rounds of walks per vertex (FN-Multi)
    window: int = 10
    dim: int = 128
    negatives: int = 5
    epochs: int = 1
    batch_size: int = 1024
    lr: float = 0.025
    mode: str = "exact"           # exact | approx
    approx_eps: float = 1e-3
    cap: Optional[int] = None     # cold row width (None -> FN-Base layout)
    seed: int = 0


def generate_walks(g: CSRGraph, cfg: Node2VecConfig,
                   mesh: Optional[Mesh] = None) -> np.ndarray:
    """All rounds of walks, [r * n, walk_length]."""
    pg = PaddedGraph.build(g, cap=cfg.cap)
    params = WalkParams(p=cfg.p, q=cfg.q, length=cfg.walk_length,
                        mode=cfg.mode, approx_eps=cfg.approx_eps)
    rounds = []
    for r in range(cfg.num_walks):
        seed = cfg.seed * 1000003 + r
        if mesh is None:
            w = simulate_walks(pg, np.arange(g.n), seed=seed, params=params)
            rounds.append(np.asarray(w))
        else:
            w, drops = distributed_walks(pg, mesh, seed=seed, params=params)
            rounds.append(np.asarray(w)[:g.n])
    return np.concatenate(rounds, axis=0)


def train_embeddings(g: CSRGraph, walks: np.ndarray,
                     cfg: Node2VecConfig) -> np.ndarray:
    """SGNS over the walk corpus; returns L2-normalized [n, dim] embeddings."""
    scfg = SGNSConfig(vocab=g.n, dim=cfg.dim, negatives=cfg.negatives)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(scfg, key)
    opt = adam(cfg.lr)
    opt_state = opt.init(params)
    for batch in walks_to_sgns_batches(walks, g.n, cfg.window, cfg.negatives,
                                       cfg.batch_size, seed=cfg.seed,
                                       epochs=cfg.epochs):
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = train_step(params, opt_state, jbatch, opt)
    return np.asarray(normalize_embeddings(params))


def node2vec(g: CSRGraph, cfg: Node2VecConfig,
             mesh: Optional[Mesh] = None) -> np.ndarray:
    walks = generate_walks(g, cfg, mesh=mesh)
    return train_embeddings(g, walks, cfg)
