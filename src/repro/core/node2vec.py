"""End-to-end Node2Vec driver: graph -> Fast-Node2Vec walks -> SGNS embeddings.

This composes the paper's two stages as a first-class framework feature. The
walk stage runs r rounds (paper: r walks per vertex == FN-Multi rounds)
through ``repro.engine.WalkEngine`` — the single entry point over all walk
backends — using its streaming ``rounds()`` iterator, so SGNS batch
construction for round *k* overlaps the (async-dispatched) walk of round
*k+1*. ``Node2VecConfig`` no longer duplicates the walk hyper-parameters in
a second dataclass: :meth:`Node2VecConfig.plan` derives the ``WalkPlan`` and
there is no ``mesh is None`` branch anywhere — backend selection is the
plan's job.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.graph import CSRGraph
from repro.core.skipgram import (SGNSConfig, init_params, normalize_embeddings,
                                 train_step)
from repro.data.corpus import walks_to_sgns_batches
from repro.engine import WalkEngine, WalkPlan
from repro.optim.optimizers import adam


@dataclasses.dataclass
class Node2VecConfig:
    p: float = 1.0
    q: float = 1.0
    walk_length: int = 80
    num_walks: int = 10           # r: rounds of walks per vertex (FN-Multi)
    window: int = 10
    dim: int = 128
    negatives: int = 5
    epochs: int = 1
    batch_size: int = 1024
    lr: float = 0.025
    mode: str = "exact"           # exact | approx | approx_always
    approx_eps: float = 1e-3
    sgns_backend: str = "jnp"     # stage-2 gradient backend: jnp | fused
                                  # (the Pallas kernel, repro.kernels.sgns)
    cap: Optional[int] = None     # cold row width (None -> FN-Base layout)
    seed: int = 0
    backend: Optional[str] = None  # None -> sharded iff a mesh is given
    capacity: Optional[int] = None  # sharded request capacity per dest
    strict_drops: bool = False     # raise instead of warn on dropped requests
    pipeline: bool = False         # async superstep pipeline (WalkPlan doc)

    def plan(self, mesh: Optional[Mesh] = None) -> WalkPlan:
        """The walk-stage half of this config as a ``WalkPlan`` — the single
        source of walk hyper-parameters (no duplicated dataclass)."""
        backend = self.backend or (
            "sharded" if mesh is not None else "reference")
        return WalkPlan(p=self.p, q=self.q, length=self.walk_length,
                        mode=self.mode, approx_eps=self.approx_eps,
                        backend=backend, cap=self.cap,
                        capacity=self.capacity,
                        strict_drops=self.strict_drops,
                        pipeline=self.pipeline)


def generate_walks(g: CSRGraph, cfg: Node2VecConfig,
                   mesh: Optional[Mesh] = None) -> np.ndarray:
    """All rounds of walks, [r * n, walk_length]."""
    engine = WalkEngine.build(g, cfg.plan(mesh), mesh=mesh)
    rounds, dropped = [], 0
    for res in engine.rounds(cfg.num_walks, seed=cfg.seed):
        rounds.append(res.walks)
        dropped += res.stats.dropped
    if dropped:
        warnings.warn(
            f"generate_walks: {dropped} dropped NEIG requests across "
            f"{cfg.num_walks} rounds — the corpus under-samples those steps",
            RuntimeWarning, stacklevel=2)
    return np.concatenate(rounds, axis=0)


def train_embeddings(g: CSRGraph, walks: np.ndarray,
                     cfg: Node2VecConfig) -> np.ndarray:
    """SGNS over the walk corpus; returns L2-normalized [n, dim] embeddings."""
    scfg = SGNSConfig(vocab=g.n, dim=cfg.dim, negatives=cfg.negatives)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(scfg, key)
    opt = adam(cfg.lr)
    opt_state = opt.init(params)
    for batch in walks_to_sgns_batches(walks, g.n, cfg.window, cfg.negatives,
                                       cfg.batch_size, seed=cfg.seed,
                                       epochs=cfg.epochs):
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = train_step(params, opt_state, jbatch, opt)
    return np.asarray(normalize_embeddings(params))


def node2vec(g: CSRGraph, cfg: Node2VecConfig,
             mesh: Optional[Mesh] = None) -> np.ndarray:
    walks = generate_walks(g, cfg, mesh=mesh)
    return train_embeddings(g, walks, cfg)
