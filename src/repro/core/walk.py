"""Single-device reference walk engine (FN-Base / FN-Cache / FN-Approx).

This is the executable specification of the paper's Algorithm 1 and its
optimizations, fully vectorized over walkers with a ``lax.scan`` over
supersteps (one scan iteration == one Pregel superstep; the BSP barrier is
implicit in SPMD dataflow).

RNG discipline: the key for walker ``i`` at step ``s`` is
``fold_in(fold_in(seed, i), s)`` — a pure function of (walker, step), never of
device layout. The distributed engine therefore produces **bit-identical**
walks (tested), which is how we validate the multi-device implementation
against this reference.

Modes:
  * ``exact``  — full 2nd-order sampling everywhere (FN-Base / FN-Cache;
    which one you get is a property of the PaddedGraph layout: cap == max
    degree -> FN-Base, cap < max degree + hot cache -> FN-Cache).
  * ``approx`` — FN-Approx: at a popular (hot) vertex v reached from an
    unpopular u, if the Eq. 2-3 bound gap < eps, sample from the *static*
    1st-order alias table: O(1) instead of O(deg) (paper §3.4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.alias import alias_sample
from repro.core.graph import PAD_ID, PaddedGraph
from repro.core.transition import approx_gap, sample_slot, unnormalized_probs


@dataclasses.dataclass(frozen=True)
class WalkParams:
    p: float = 1.0
    q: float = 1.0
    length: int = 80
    mode: str = "exact"          # "exact" | "approx"
    approx_eps: float = 1e-3


def walker_key(seed_key: jax.Array, walker_id: jnp.ndarray,
               step: jnp.ndarray) -> jax.Array:
    """Layout-independent per-(walker, step) key."""
    return jax.random.fold_in(jax.random.fold_in(seed_key, walker_id), step)


def unified_row(pg: PaddedGraph, v: jnp.ndarray):
    """Full-width (max(cap, hot_cap)) row lookup for one vertex id.

    Returns (ids, w, alias_p, alias_i, is_hot). Hot vertices read the
    replicated hot cache (exact, full degree); cold vertices read the capped
    local row. Output width is hot_cap (>= cap), pads appended.
    """
    hpos = pg.hot_pos[v]
    is_hot = hpos >= 0
    h = jnp.maximum(hpos, 0)
    width = pg.hot_cap

    def padded(x, fill):
        pad = jnp.full((width - pg.cap,), fill, x.dtype)
        return jnp.concatenate([x, pad])

    cold_ids = padded(pg.adj[v], PAD_ID)
    cold_w = padded(pg.wgt[v], 0.0)
    cold_ap = padded(pg.alias_p[v], 0.0)
    cold_ai = padded(pg.alias_i[v], 0)
    ids = jnp.where(is_hot, pg.hot_adj[h], cold_ids)
    w = jnp.where(is_hot, pg.hot_wgt[h], cold_w)
    ap = jnp.where(is_hot, pg.hot_alias_p[h], cold_ap)
    ai = jnp.where(is_hot, pg.hot_alias_i[h], cold_ai)
    return ids, w, ap, ai, is_hot


def _first_step(pg: PaddedGraph, v: jnp.ndarray, key: jax.Array):
    """Step 0: 1st-order draw from static edge weights via the alias table."""
    ids, _, ap, ai, _ = unified_row(pg, v)
    slot = alias_sample(key, ap, ai, pg.deg[v])
    nxt = ids[slot]
    return jnp.where(pg.deg[v] > 0, nxt, v)


def _second_order_step(pg: PaddedGraph, u: jnp.ndarray, v: jnp.ndarray,
                       prev_ids: jnp.ndarray, key: jax.Array,
                       params: WalkParams):
    """One 2nd-order move for one walker. Returns (next_id, v_row_ids)."""
    ids, w, ap, ai, is_hot = unified_row(pg, v)
    probs = unnormalized_probs(ids, w, u, prev_ids, params.p, params.q)
    k_exact, k_approx = jax.random.split(key)
    exact_slot = sample_slot(k_exact, probs)
    if params.mode == "approx":
        gap = approx_gap(pg.deg[u], pg.deg[v], pg.w_min[v], pg.w_max[v],
                         params.p, params.q)
        u_hot = pg.hot_pos[u] >= 0
        use_approx = is_hot & (~u_hot) & (gap < params.approx_eps)
        approx_slot = alias_sample(k_approx, ap, ai, pg.deg[v])
        slot = jnp.where(use_approx, approx_slot, exact_slot)
    elif params.mode == "approx_always":
        # beyond-paper: hot vertices always take the O(1) alias path
        # (semantics mirror of walk_distributed; quality measured in
        # benchmarks/bench_accuracy)
        approx_slot = alias_sample(k_approx, ap, ai, pg.deg[v])
        slot = jnp.where(is_hot, approx_slot, exact_slot)
    else:
        slot = exact_slot
    nxt = ids[slot]
    nxt = jnp.where(pg.deg[v] > 0, nxt, v)  # dead end: stay
    return nxt, ids


@functools.partial(jax.jit, static_argnames=("params", "length"))
def _simulate(pg: PaddedGraph, starts: jnp.ndarray, walker_ids: jnp.ndarray,
              seed_key: jax.Array, params: WalkParams, length: int):
    w = starts.shape[0]

    k0 = jax.vmap(lambda i: walker_key(seed_key, i, 0))(walker_ids)
    v1 = jax.vmap(lambda v, k: _first_step(pg, v, k))(starts, k0)
    prev_ids0 = jax.vmap(lambda v: unified_row(pg, v)[0])(starts)

    def body(carry, s):
        u, v, prev_ids = carry
        ks = jax.vmap(lambda i: walker_key(seed_key, i, s))(walker_ids)
        nxt, v_ids = jax.vmap(
            lambda uu, vv, pr, kk: _second_order_step(pg, uu, vv, pr, kk,
                                                      params))(
                u, v, prev_ids, ks)
        return (v, nxt, v_ids), v

    (_, v_last, _), steps = jax.lax.scan(
        body, (starts, v1, prev_ids0), jnp.arange(1, length, dtype=jnp.int32))
    # walks[:, 0] = first sampled step, then one column per later step
    walks = jnp.concatenate(
        [steps.T, v_last[:, None]], axis=1) if length > 1 else v1[:, None]
    return walks


def simulate_walks(pg: PaddedGraph, starts: jnp.ndarray, seed: int,
                   params: WalkParams,
                   walker_ids: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Simulate ``len(starts)`` biased walks of ``params.length`` steps.

    Returns [W, length] i32: the sampled steps (excluding the start vertex,
    matching Algorithm 1 which stores step[0] = first sampled move).
    """
    starts = jnp.asarray(starts, jnp.int32)
    if walker_ids is None:
        walker_ids = jnp.arange(starts.shape[0], dtype=jnp.int32)
    key = jax.random.PRNGKey(seed)
    return _simulate(pg, starts, walker_ids, key, params, params.length)
