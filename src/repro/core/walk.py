"""Single-device walk engine (FN-Base / FN-Cache / FN-Approx) — the
executable specification of the paper's Algorithm 1, and the substrate for
two ``WalkEngine`` backends:

* ``"reference"`` — all sampling in plain jnp;
* ``"fused"``     — the exact 2nd-order draw runs in the Pallas kernel
  (``kernels.node2vec_step`` via the ``kernels.ops`` padding contract),
  interpret mode off-TPU. Both are this module's ``run_reference`` with a
  different :class:`~repro.engine.sampler.Sampler`.

The walk is fully vectorized over walkers with a ``lax.scan`` over supersteps
(one scan iteration == one Pregel superstep; the BSP barrier is implicit in
SPMD dataflow). All sampling math lives in ``repro.engine.sampler`` —
shared, not duplicated, with the distributed engine (DESIGN.md §3).

RNG discipline: the key for walker ``i`` at step ``s`` is
``fold_in(fold_in(seed, i), s)`` — a pure function of (walker, step), never of
device layout. The distributed engine therefore produces **bit-identical**
walks (tested), which is how we validate the multi-device implementation
against this reference.

Modes:
  * ``exact``  — full 2nd-order sampling everywhere (FN-Base / FN-Cache;
    which one you get is a property of the PaddedGraph layout: cap == max
    degree -> FN-Base, cap < max degree + hot cache -> FN-Cache).
  * ``approx`` — FN-Approx: at a popular (hot) vertex v reached from an
    unpopular u, if the Eq. 2-3 bound gap < eps, sample from the *static*
    1st-order alias table: O(1) instead of O(deg) (paper §3.4).

The ``simulate_walks`` shim (deprecated in PR 7) was removed in PR 9; all
callers go through ``repro.engine.WalkEngine`` (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core.graph import PAD_ID, PaddedGraph
from repro.engine.sampler import HotContext, Sampler, first_order_slots


@dataclasses.dataclass(frozen=True)
class WalkParams:
    """Legacy walk hyper-parameters. Prefer ``repro.engine.WalkPlan``, which
    adds the backend/layout knobs; this remains as the shim-level view."""
    p: float = 1.0
    q: float = 1.0
    length: int = 80
    mode: str = "exact"          # "exact" | "approx" | "approx_always"
    approx_eps: float = 1e-3

    def sampler(self, fused: bool = False) -> Sampler:
        return Sampler(p=self.p, q=self.q, mode=self.mode,
                       eps=self.approx_eps, fused=fused)


def walker_key(seed_key: jax.Array, walker_id: jnp.ndarray,
               step: jnp.ndarray) -> jax.Array:
    """Layout-independent per-(walker, step) key."""
    return jax.random.fold_in(jax.random.fold_in(seed_key, walker_id), step)


_DEPRECATION_WARNED: set = set()


def warn_deprecated_once(name: str, api: str) -> None:
    """One-shot ``DeprecationWarning`` for legacy shims (currently the
    ``load_graph``/``load_dataset`` names over ``repro.data.open_graph``).
    Shims sit on loops and fixtures, where one warning per process is
    actionable and one per call is noise."""
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {api} "
        f"(this warning fires once per process)",
        DeprecationWarning, stacklevel=3)


def reset_deprecation_warnings() -> None:
    """Re-arm the one-shot shim warnings (test isolation)."""
    _DEPRECATION_WARNED.clear()


def unified_row(pg: PaddedGraph, v: jnp.ndarray):
    """Full-width (max(cap, hot_cap)) row lookup for one vertex id.

    Returns (ids, w, alias_p, alias_i, is_hot). Hot vertices read the
    replicated hot cache (exact, full degree); cold vertices read the capped
    local row. Output width is hot_cap (>= cap), pads appended.
    """
    hpos = pg.hot_pos[v]
    is_hot = hpos >= 0
    h = jnp.maximum(hpos, 0)
    width = pg.hot_cap

    def padded(x, fill):
        pad = jnp.full((width - pg.cap,), fill, x.dtype)
        return jnp.concatenate([x, pad])

    cold_ids = padded(pg.adj[v], PAD_ID)
    cold_w = padded(pg.wgt[v], 0.0)
    cold_ap = padded(pg.alias_p[v], 0.0)
    cold_ai = padded(pg.alias_i[v], 0)
    ids = jnp.where(is_hot, pg.hot_adj[h], cold_ids)
    w = jnp.where(is_hot, pg.hot_wgt[h], cold_w)
    ap = jnp.where(is_hot, pg.hot_alias_p[h], cold_ap)
    ai = jnp.where(is_hot, pg.hot_alias_i[h], cold_ai)
    return ids, w, ap, ai, is_hot


def _batched_rows(pg: PaddedGraph, v: jnp.ndarray):
    return jax.vmap(lambda vv: unified_row(pg, vv))(v)


@functools.partial(jax.jit, static_argnames=("sampler", "length"))
def _simulate(pg: PaddedGraph, starts: jnp.ndarray, walker_ids: jnp.ndarray,
              seed_key: jax.Array, sampler: Sampler, length: int):
    # step 0: 1st-order draw from static edge weights via the alias table
    k0 = jax.vmap(lambda i: walker_key(seed_key, i, 0))(walker_ids)
    ids0, _, ap0, ai0, _ = _batched_rows(pg, starts)
    deg0 = pg.deg[starts]
    slot0 = first_order_slots(k0, ap0, ai0, deg0)
    nxt0 = jnp.take_along_axis(ids0, slot0[:, None], axis=1)[:, 0]
    v1 = jnp.where(deg0 > 0, nxt0, starts)

    def body(carry, s):
        u, v, prev_ids = carry
        keys = jax.vmap(lambda i: walker_key(seed_key, i, s))(walker_ids)
        ids, w, ap, ai, is_hot = _batched_rows(pg, v)
        hot = None
        if sampler.mode != "exact":
            hot = HotContext(
                is_hot_v=is_hot, is_hot_u=pg.hot_pos[u] >= 0,
                deg_u=pg.deg[u], deg_v=pg.deg[v],
                w_min_v=pg.w_min[v], w_max_v=pg.w_max[v],
                alias_p=ap, alias_i=ai, alias_deg=pg.deg[v])
        choice = sampler.choose(keys, ids, w, u, prev_ids, hot)
        nxt = jnp.take_along_axis(ids, choice.slot()[:, None], axis=1)[:, 0]
        nxt = jnp.where(pg.deg[v] > 0, nxt, v)  # dead end: stay
        return (v, nxt, ids), v

    (_, v_last, _), steps = jax.lax.scan(
        body, (starts, v1, ids0), jnp.arange(1, length, dtype=jnp.int32))
    # walks[:, 0] = first sampled step, then one column per later step
    walks = jnp.concatenate(
        [steps.T, v_last[:, None]], axis=1) if length > 1 else v1[:, None]
    return walks


def run_reference(pg: PaddedGraph, starts: jnp.ndarray,
                  walker_ids: jnp.ndarray, seed_key: jax.Array,
                  sampler: Sampler, length: int) -> jnp.ndarray:
    """Single-device backend entry point used by ``WalkEngine``."""
    return _simulate(pg, starts, walker_ids, seed_key, sampler=sampler,
                     length=length)


@functools.partial(jax.jit, static_argnames=("sampler", "length"))
def run_fused_persistent(pg: PaddedGraph, starts: jnp.ndarray,
                         walker_ids: jnp.ndarray, seed_key: jax.Array,
                         sampler: Sampler, length: int) -> jnp.ndarray:
    """Fused backend with ``WalkPlan.pipeline``: one Pallas call runs every
    2nd-order superstep, carrying the prev-neighbor rows in VMEM instead of
    re-reading a [W, DP] block from HBM per step (``kernels.node2vec_walk``).

    Requires exact mode + FN-Base layout (empty hot set; the engine gates
    this). Step 0 (first-order alias draw) and the per-(walker, step)
    uniforms stay on the host — the RNG contract is a pure function of
    (walker, step), so walks are bit-identical to ``run_reference``.
    """
    from repro.kernels.ops import node2vec_walk_op

    k0 = jax.vmap(lambda i: walker_key(seed_key, i, 0))(walker_ids)
    ids0, _, ap0, ai0, _ = _batched_rows(pg, starts)
    deg0 = pg.deg[starts]
    slot0 = first_order_slots(k0, ap0, ai0, deg0)
    nxt0 = jnp.take_along_axis(ids0, slot0[:, None], axis=1)[:, 0]
    v1 = jnp.where(deg0 > 0, nxt0, starts)
    if length == 1:
        return v1[:, None]

    def step_rand(i):
        def at(s):
            k = walker_key(seed_key, i, s)
            return jax.random.uniform(jax.random.split(k)[0])
        return jax.vmap(at)(jnp.arange(1, length, dtype=jnp.int32))

    rand = jax.vmap(step_rand)(walker_ids)            # [W, length-1]
    tail = node2vec_walk_op(pg.adj, pg.wgt, pg.deg, starts, v1, rand,
                            sampler.p, sampler.q)
    return jnp.concatenate([v1[:, None], tail], axis=1)
