"""2nd-order Node2Vec transition probabilities — on-demand (paper §3.2).

The walk moved u -> v; for every candidate x in N(v):

    alpha_pq(u, v, x) = 1/p  if x == u          (dist(u,x) == 0)
                        1    if x in N(u)       (dist(u,x) == 1)
                        1/q  otherwise          (dist(u,x) == 2)
    pi_vx = alpha * w_vx   (normalized over N(v))

Nothing is ever precomputed or stored per (u, v) pair — this is the paper's
central memory-saving idea (Eq. 1: storing all pairs costs 8*sum(d_i^2) bytes).

Membership x in N(u) is a binary search against the *sorted* neighbor row of u
(pads are PAD_ID = i32 max, so they sort last and never match).

``approx_gap`` implements the FN-Approx bounds (paper Eq. 2-3), generalized to
any (p, q) ordering (the paper assumes 1/p <= 1 <= 1/q).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PAD_ID, CSRGraph


def membership(prev_sorted: jnp.ndarray, cand_ids: jnp.ndarray) -> jnp.ndarray:
    """For each candidate id, is it present in the sorted row ``prev_sorted``?

    prev_sorted: [Dp] i32 (ascending, PAD_ID padded); cand_ids: [D] i32.
    """
    dp = prev_sorted.shape[-1]
    pos = jnp.searchsorted(prev_sorted, cand_ids)
    pos = jnp.minimum(pos, dp - 1)
    hit = prev_sorted[pos] == cand_ids
    return hit & (cand_ids != PAD_ID)


def unnormalized_probs(cand_ids: jnp.ndarray, cand_w: jnp.ndarray,
                       u: jnp.ndarray, prev_sorted: jnp.ndarray,
                       p: float, q: float) -> jnp.ndarray:
    """alpha_pq * w over one candidate row. Shapes: [D], [D], [], [Dp]."""
    is_u = cand_ids == u
    common = membership(prev_sorted, cand_ids)
    alpha = jnp.where(is_u, 1.0 / p, jnp.where(common, 1.0, 1.0 / q))
    valid = cand_ids != PAD_ID
    return jnp.where(valid, alpha * cand_w, 0.0)


def sample_slot(key: jax.Array, probs: jnp.ndarray) -> jnp.ndarray:
    """Inverse-CDF draw over an unnormalized prob row; returns slot index."""
    cum = jnp.cumsum(probs)
    total = cum[-1]
    r = jax.random.uniform(key) * total
    idx = jnp.searchsorted(cum, r, side="right")
    return jnp.minimum(idx, probs.shape[-1] - 1).astype(jnp.int32)


def approx_gap(deg_u: jnp.ndarray, deg_v: jnp.ndarray, w_min_v: jnp.ndarray,
               w_max_v: jnp.ndarray, p: float, q: float) -> jnp.ndarray:
    """Width of the [LB, UB] interval for a single transition probability at v
    given only scalar summaries (paper Eq. 2-3, generalized).

    The number of common neighbors among v's non-u candidates is some
    c in [0, m], m = min(deg_u, deg_v - 1); bounding the numerator/denominator
    over c and the edge-weight range yields layout-free bounds, so the check
    costs O(1) and needs **no** neighbor traffic.
    """
    inv_p, inv_q = 1.0 / p, 1.0 / q
    dv = jnp.maximum(deg_v.astype(jnp.float32), 2.0)
    m = jnp.minimum(deg_u.astype(jnp.float32), dv - 1.0)
    base = inv_p + (dv - 1.0) * inv_q
    den_hi = w_max_v * (base + jnp.maximum(0.0, m * (1.0 - inv_q)))
    den_lo = w_min_v * (base + jnp.minimum(0.0, m * (1.0 - inv_q)))
    num_hi = max(1.0, inv_q) * w_max_v
    num_lo = min(1.0, inv_q) * w_min_v
    return num_hi / jnp.maximum(den_lo, 1e-30) - num_lo / jnp.maximum(
        den_hi, 1e-30)


def brute_force_probs(g: CSRGraph, u: int, v: int, p: float,
                      q: float) -> Dict[int, float]:
    """Python-set oracle for tests: exact normalized transition probs at v
    given previous vertex u."""
    nu = set(int(x) for x in g.neighbors(u))
    probs = {}
    for x, w in zip(g.neighbors(v), g.weights(v)):
        x = int(x)
        if x == u:
            a = 1.0 / p
        elif x in nu:
            a = 1.0
        else:
            a = 1.0 / q
        probs[x] = probs.get(x, 0.0) + a * float(w)
    total = sum(probs.values())
    return {x: pw / total for x, pw in probs.items()} if total > 0 else {}
