"""TrillionG-style RMAT graph generation (paper §4.1).

Generates the paper's three synthetic families:

* ``er(k)``      — (0.25, 0.25, 0.25, 0.25), avg degree 10 (ER-K graphs)
* ``wec(k)``     — (0.18, 0.25, 0.25, 0.32), avg degree ~100 (WeChat-like)
* ``skew(s, k)`` — b = c = 0.25, d = S*a, avg degree ~100 (Skew-S graphs)

Each edge draws one quadrant bit pair per level: P(row=1) = c+d, then
P(col=1 | row) per the conditional RMAT split — fully vectorized over
[num_edges, K] in numpy. Graphs are symmetrized and deduped by
``CSRGraph.from_edges`` like the paper's undirected treatment.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import CSRGraph


def rmat_edges(k: int, num_edges: int, a: float, b: float, c: float, d: float,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Draw ``num_edges`` directed RMAT edges over 2^k vertices."""
    assert abs(a + b + c + d - 1.0) < 1e-6
    rng = np.random.default_rng(seed)
    p_row1 = c + d
    p_col1_row0 = b / max(a + b, 1e-12)
    p_col1_row1 = d / max(c + d, 1e-12)
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    for _ in range(k):
        row = rng.random(num_edges) < p_row1
        p_col = np.where(row, p_col1_row1, p_col1_row0)
        col = rng.random(num_edges) < p_col
        src = (src << 1) | row
        dst = (dst << 1) | col
    return src, dst


def rmat_graph(k: int, avg_degree: float, a: float, b: float, c: float,
               d: float, seed: int = 0) -> CSRGraph:
    n = 1 << k
    # undirected symmetrization doubles edge endpoints; draw n*avg/2 edges
    num_edges = int(n * avg_degree / 2)
    src, dst = rmat_edges(k, num_edges, a, b, c, d, seed)
    return CSRGraph.from_edges(n, src, dst, undirected=True)


def er(k: int, avg_degree: float = 10.0, seed: int = 0) -> CSRGraph:
    """ER-K: uniform quadrants, no degree skew (paper Table 1)."""
    return rmat_graph(k, avg_degree, 0.25, 0.25, 0.25, 0.25, seed)


def wec(k: int, avg_degree: float = 100.0, seed: int = 0) -> CSRGraph:
    """WeC-K: WeChat-like social graph, (0.18, 0.25, 0.25, 0.32)."""
    return rmat_graph(k, avg_degree, 0.18, 0.25, 0.25, 0.32, seed)


def skew(s: float, k: int = 22, avg_degree: float = 100.0,
         seed: int = 0) -> CSRGraph:
    """Skew-S: b = c = 0.25, d = S*a, a + d = 0.5 (paper §4.1)."""
    a = 0.5 / (1.0 + s)
    d = s * a
    return rmat_graph(k, avg_degree, a, 0.25, 0.25, d, seed)


def sbm_labeled(n: int, num_communities: int, p_in: float, p_out: float,
                seed: int = 0) -> tuple[CSRGraph, np.ndarray]:
    """Stochastic-block-model labeled graph — stands in for BlogCatalog in the
    node-classification accuracy experiment (paper Fig. 6): vertices carry
    community labels; embeddings good enough to linearly separate communities
    score high micro/macro-F1."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_communities, size=n)
    # sample edges by expected count per pair class (sparse sampling)
    exp_in = int(p_in * n * (n / num_communities) / 2)
    exp_out = int(p_out * n * n / 2)
    si = rng.integers(0, n, size=exp_in * 2)
    di_base = rng.integers(0, n, size=exp_in * 2)
    same = labels[si] == labels[di_base]
    si, di = si[same][:exp_in], di_base[same][:exp_in]
    so = rng.integers(0, n, size=exp_out * 2)
    do = rng.integers(0, n, size=exp_out * 2)
    diff = labels[so] != labels[do]
    so, do = so[diff][:exp_out], do[diff][:exp_out]
    src = np.concatenate([si, so])
    dst = np.concatenate([di, do])
    return CSRGraph.from_edges(n, src, dst, undirected=True), labels
