"""Graph containers for Fast-Node2Vec.

Two representations:

* :class:`CSRGraph` — host-side (numpy) compressed-sparse-row graph. This is
  the build/IO format: edge lists come in, get symmetrized/deduped, and the
  per-row neighbor lists are **sorted ascending** (membership tests during the
  2nd-order walk are binary searches).

* :class:`PaddedGraph` — device-side (jnp) degree-capped padded adjacency plus
  a replicated **hot cache** holding the full rows of popular vertices. This is
  the TPU adaptation of the paper's FN-Cache: the static-shape exchange only
  ever carries rows of width ``cap`` (cold vertices); every vertex with degree
  > ``cap`` lives in the hot cache, which is replicated on all shards, so its
  neighbor list never crosses ICI (paper §3.4, FN-Cache).

Pad convention: neighbor ids are padded with ``PAD_ID`` (i32 max) so rows stay
sorted-ascending (pads sort last) and ``searchsorted`` membership remains
correct; weights are padded with 0 so padded lanes carry zero probability.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alias import build_alias_rows

PAD_ID = np.iinfo(np.int32).max


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR graph with sorted neighbor lists."""

    n: int
    row_ptr: np.ndarray  # [n+1] int64
    col: np.ndarray      # [m]   int32, sorted within each row
    wgt: np.ndarray      # [m]   float32, > 0

    @property
    def m(self) -> int:
        return int(self.col.shape[0])

    @property
    def deg(self) -> np.ndarray:
        return (self.row_ptr[1:] - self.row_ptr[:-1]).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.deg.max()) if self.n else 0

    def neighbors(self, v: int) -> np.ndarray:
        return self.col[self.row_ptr[v]:self.row_ptr[v + 1]]

    def weights(self, v: int) -> np.ndarray:
        return self.wgt[self.row_ptr[v]:self.row_ptr[v + 1]]

    @staticmethod
    def from_edges(
        n: int,
        src: np.ndarray,
        dst: np.ndarray,
        wgt: Optional[np.ndarray] = None,
        undirected: bool = True,
        dedup: bool = True,
    ) -> "CSRGraph":
        """Build a CSR graph from an edge list.

        Self loops are dropped; duplicate edges are deduped (first weight
        wins); for ``undirected`` the reverse edges are added before dedup.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if wgt is None:
            wgt = np.ones(src.shape[0], dtype=np.float32)
        wgt = np.asarray(wgt, dtype=np.float32)
        keep = src != dst
        src, dst, wgt = src[keep], dst[keep], wgt[keep]
        if undirected:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            wgt = np.concatenate([wgt, wgt])
        # sort by (src, dst); dedup
        order = np.lexsort((dst, src))
        src, dst, wgt = src[order], dst[order], wgt[order]
        if dedup and src.size:
            first = np.ones(src.shape[0], dtype=bool)
            first[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
            src, dst, wgt = src[first], dst[first], wgt[first]
        counts = np.bincount(src, minlength=n).astype(np.int64)
        row_ptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=row_ptr[1:])
        return CSRGraph(n=n, row_ptr=row_ptr, col=dst.astype(np.int32),
                        wgt=wgt.astype(np.float32))

    def trim_top_weights(self, k: int) -> "CSRGraph":
        """Spark-Node2Vec's quality-destroying trim: keep only the ``k``
        highest-weight edges per vertex (paper §2.2). Used as the baseline."""
        keep_idx = []
        for v in range(self.n):
            lo, hi = self.row_ptr[v], self.row_ptr[v + 1]
            if hi - lo <= k:
                keep_idx.append(np.arange(lo, hi))
            else:
                w = self.wgt[lo:hi]
                top = np.argpartition(-w, k - 1)[:k]
                keep_idx.append(lo + np.sort(top))
        keep = np.concatenate(keep_idx) if keep_idx else np.zeros(0, np.int64)
        src = np.repeat(np.arange(self.n, dtype=np.int64),
                        [len(ix) for ix in keep_idx])
        return CSRGraph.from_edges(self.n, src, self.col[keep].astype(np.int64),
                                   self.wgt[keep], undirected=False)

    def transition_table_bytes(self) -> int:
        """Paper Eq. 1: memory to pre-store *all* 2nd-order transition
        probabilities with 8-byte alias entries — the quantity on-demand
        computation avoids."""
        d = self.deg.astype(np.int64)
        return int(8 * np.sum(d * d))


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["adj", "wgt", "deg", "alias_p", "alias_i", "w_min", "w_max",
                 "hot_pos", "hot_ids", "hot_adj", "hot_wgt", "hot_alias_p",
                 "hot_alias_i"],
    meta_fields=["n", "cap", "hot_cap"])
@dataclasses.dataclass
class PaddedGraph:
    """Device-side degree-capped adjacency + replicated hot cache.

    Invariant: every vertex with ``deg > cap`` is hot. Hot vertices' cold rows
    hold only their first ``cap`` neighbors (never read for sampling); exact
    reads for hot vertices go through the replicated hot arrays.
    """

    n: int
    cap: int              # cold row width  (D_cold)
    hot_cap: int          # hot row width   (D_hot >= max degree of hot set)
    adj: jnp.ndarray      # [n, cap]  i32, PAD_ID padded, sorted
    wgt: jnp.ndarray      # [n, cap]  f32, 0 padded
    deg: jnp.ndarray      # [n] i32   true degree
    alias_p: jnp.ndarray  # [n, cap]  f32 — 1st-order alias table (static weights)
    alias_i: jnp.ndarray  # [n, cap]  i32 — alias companion (local slot index)
    w_min: jnp.ndarray    # [n] f32  min edge weight per vertex (1.0 if isolated)
    w_max: jnp.ndarray    # [n] f32
    hot_pos: jnp.ndarray  # [n] i32  position in hot arrays, -1 if cold
    hot_ids: jnp.ndarray      # [K] i32 (K >= 1; row 0 is a dummy if no hot)
    hot_adj: jnp.ndarray      # [K, hot_cap] i32
    hot_wgt: jnp.ndarray      # [K, hot_cap] f32
    hot_alias_p: jnp.ndarray  # [K, hot_cap] f32
    hot_alias_i: jnp.ndarray  # [K, hot_cap] i32

    @property
    def num_hot(self) -> int:
        return int(self.hot_ids.shape[0])

    @staticmethod
    def build(g: CSRGraph, cap: Optional[int] = None,
              hot_cap: Optional[int] = None) -> "PaddedGraph":
        """``cap=None`` → cap = max degree (FN-Base layout: no hot set)."""
        deg = g.deg
        max_deg = g.max_degree
        if cap is None or cap >= max(max_deg, 1):
            cap = max(max_deg, 1)
        cap = max(int(cap), 1)
        hot_mask = deg > cap
        hot_vertices = np.nonzero(hot_mask)[0].astype(np.int32)
        k = max(1, len(hot_vertices))
        if hot_cap is None:
            hot_cap = int(deg[hot_vertices].max()) if len(hot_vertices) else cap
        hot_cap = max(int(hot_cap), cap)

        def pack_rows(vertices: np.ndarray, width: int):
            rows = np.full((len(vertices), width), PAD_ID, dtype=np.int32)
            wrows = np.zeros((len(vertices), width), dtype=np.float32)
            for i, v in enumerate(vertices):
                lo, hi = g.row_ptr[v], g.row_ptr[v + 1]
                d = min(int(hi - lo), width)
                rows[i, :d] = g.col[lo:lo + d]
                wrows[i, :d] = g.wgt[lo:lo + d]
            return rows, wrows

        all_v = np.arange(g.n, dtype=np.int32)
        adj, wgt = pack_rows(all_v, cap)
        if len(hot_vertices):
            hot_list = hot_vertices
            hot_adj, hot_wgt = pack_rows(hot_list, hot_cap)
        else:
            # sentinel hot set that can never match a real vertex id
            hot_list = np.full(1, PAD_ID, np.int32)
            hot_adj = np.full((1, hot_cap), PAD_ID, np.int32)
            hot_wgt = np.zeros((1, hot_cap), np.float32)

        hot_pos = np.full(g.n, -1, dtype=np.int32)
        if len(hot_vertices):
            hot_pos[hot_vertices] = np.arange(len(hot_vertices), dtype=np.int32)

        alias_p, alias_i = build_alias_rows(wgt)
        hot_alias_p, hot_alias_i = build_alias_rows(hot_wgt)

        w_min = np.ones(g.n, dtype=np.float32)
        w_max = np.ones(g.n, dtype=np.float32)
        nz = deg > 0
        # vectorized per-row min/max over the padded arrays (full row in hot)
        full_w = wgt.copy()
        if len(hot_vertices):
            pass  # cold rows of hot vertices are truncated; fix below from hot
        mask = adj != PAD_ID
        with np.errstate(invalid="ignore"):
            w_min[nz] = np.where(mask, full_w, np.inf).min(axis=1)[nz]
            w_max[nz] = np.where(mask, full_w, -np.inf).max(axis=1)[nz]
        if len(hot_vertices):
            hmask = hot_adj != PAD_ID
            w_min[hot_vertices] = np.where(hmask, hot_wgt, np.inf).min(axis=1)
            w_max[hot_vertices] = np.where(hmask, hot_wgt, -np.inf).max(axis=1)

        return PaddedGraph(
            n=g.n, cap=cap, hot_cap=hot_cap,
            adj=jnp.asarray(adj), wgt=jnp.asarray(wgt),
            deg=jnp.asarray(deg), alias_p=jnp.asarray(alias_p),
            alias_i=jnp.asarray(alias_i),
            w_min=jnp.asarray(w_min), w_max=jnp.asarray(w_max),
            hot_pos=jnp.asarray(hot_pos),
            hot_ids=jnp.asarray(hot_list),
            hot_adj=jnp.asarray(hot_adj), hot_wgt=jnp.asarray(hot_wgt),
            hot_alias_p=jnp.asarray(hot_alias_p),
            hot_alias_i=jnp.asarray(hot_alias_i),
        )
