"""Vose alias method [Vose'91] — O(1) sampling from a static distribution.

The paper uses 8-byte alias entries for transition probabilities (Eq. 1); we
keep the same layout (f32 prob + i32 companion = 8 B/slot) but *only* build the
1st-order table (O(E) total), never the O(sum d_i^2) 2nd-order tables — the
central memory-saving claim of Fast-Node2Vec.

``build_alias_rows`` is the host-side (numpy) batch builder over padded weight
rows; ``alias_sample`` is the device-side O(1) draw used by the walk engines
for (a) step 0 and (b) the FN-Approx fast path at popular vertices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def build_alias(w: np.ndarray):
    """Classic Vose construction for one row. Returns (prob[f32], alias[i32])."""
    k = len(w)
    prob = np.zeros(k, dtype=np.float32)
    alias = np.zeros(k, dtype=np.int32)
    if k == 0:
        return prob, alias
    total = float(w.sum())
    if total <= 0:
        prob[:] = 1.0
        alias[:] = np.arange(k)
        return prob, alias
    scaled = w.astype(np.float64) * (k / total)
    small = [i for i in range(k) if scaled[i] < 1.0]
    large = [i for i in range(k) if scaled[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        prob[s] = scaled[s]
        alias[s] = l
        scaled[l] = (scaled[l] + scaled[s]) - 1.0
        (small if scaled[l] < 1.0 else large).append(l)
    for i in large + small:
        prob[i] = 1.0
        alias[i] = i
    return prob, alias


def build_alias_rows(wrows: np.ndarray):
    """Batched Vose over padded weight rows ``[R, D]`` (0-padded, pads strictly
    trailing). Each table is built over exactly the row's ``deg`` live slots,
    so draws use ``width = deg`` — making alias sampling independent of the
    padded layout (FN-Base vs FN-Cache produce bit-identical walks)."""
    wrows = np.asarray(wrows, dtype=np.float64)
    r, d = wrows.shape
    prob = np.zeros((r, d), dtype=np.float32)
    alias = np.zeros((r, d), dtype=np.int32)
    if r == 0 or d == 0:
        return prob, alias
    live = (wrows > 0).sum(axis=1)
    for i in np.nonzero(live > 0)[0]:
        k = int(live[i])
        p, a = build_alias(wrows[i, :k])
        prob[i, :k], alias[i, :k] = p, a
    return prob, alias


def alias_sample(key: jax.Array, prob_row: jnp.ndarray,
                 alias_row: jnp.ndarray, width=None) -> jnp.ndarray:
    """O(1) alias draw over a padded row.

    Tables are built over exactly the row's live degree, so pass
    ``width = deg(v)`` (layout-independent). Returns the sampled *slot index*
    (caller maps the slot to a neighbor id).
    """
    k1, k2 = jax.random.split(key)
    if width is None:
        width = prob_row.shape[-1]
    width = jnp.maximum(jnp.asarray(width, jnp.int32), 1)
    slot = jnp.minimum(
        (jax.random.uniform(k1) * width.astype(jnp.float32)).astype(jnp.int32),
        width - 1)
    u = jax.random.uniform(k2)
    take_alias = u >= prob_row[slot]
    return jnp.where(take_alias, alias_row[slot], slot)
