"""jamba-v0.1-52b — hybrid Mamba+attention (1:7) with MoE 16e top-2.
[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Superblock of 8: attention at offset 4, mamba elsewhere; MoE on odd layers
(16 MoE layers total). Sub-quadratic (hybrid) -> long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536, mlp_act="swiglu",
    moe_experts=16, moe_top_k=2, moe_every=2, moe_phase=1,
    attn_every=8, attn_offset=4,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    rope_theta=1e4, subquadratic=True,
)
