"""mixtral-8x22b — MoE 8 experts top-2 with sliding-window attention.
[arXiv:2401.04088; hf] 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
SWA window 4096 -> ring-buffer KV cache -> sub-quadratic long_500k decode."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768, mlp_act="swiglu",
    moe_experts=8, moe_top_k=2, moe_every=1,
    window=4096, rope_theta=1e6, subquadratic=True,
)
