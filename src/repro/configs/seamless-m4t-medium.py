"""seamless-m4t-medium — encoder-decoder, multimodal (audio frontend STUB:
input_specs supplies precomputed frame embeddings, per the assignment).
[arXiv:2308.11596; hf] 12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
Built as 12 encoder + 12 decoder layers (per-stack depth)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, enc_layers=12, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=4096, vocab=256206, mlp_act="gelu",
    cross_every=1, num_audio_frames=1024, rope_theta=1e4,
)
