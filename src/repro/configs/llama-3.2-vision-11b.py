"""llama-3.2-vision-11b — decoder with cross-attention image layers every 5th
layer (vision frontend STUB: input_specs supplies precomputed patch
embeddings). [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, mlp_act="swiglu",
    cross_every=5, num_image_tokens=1600, rope_theta=5e5,
)
