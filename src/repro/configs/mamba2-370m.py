"""mamba2-370m — attention-free SSM (SSD, state-space duality).
[arXiv:2405.21060; unverified] 48L d_model=1024 d_ff=0 vocab=50280
ssm_state=128. O(1)-state decode -> long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=0, vocab=50280, attn_every=0,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    subquadratic=True,
)
