"""Assigned input-shape sets and smoke-config reduction helpers."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

# LM-family shapes (assignment): name -> (seq_len, global_batch, step kind)
SHAPES: Dict[str, dict] = {
    "train_4k":    {"seq": 4_096,   "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32_768,  "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32_768,  "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524_288, "batch": 1,   "kind": "decode"},
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k needs a sub-quadratic decode path (ssm/hybrid/SWA);
    full-attention archs skip it (noted in DESIGN.md)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("full quadratic attention: 512k-token KV decode is "
                       "intentionally skipped (DESIGN.md §5)")
    return True, ""


def smoke_reduce(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: identical block
    pattern, tiny widths."""
    pattern = len(cfg.superblock())
    return dataclasses.replace(
        cfg,
        num_layers=pattern * min(2, cfg.num_superblocks),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, 4 // max(1, cfg.q_per_kv)),
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        moe_experts=min(cfg.moe_experts, 4),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16,
        ssm_expand=2,
        enc_layers=2 if cfg.enc_layers else 0,
        num_image_tokens=16,
        num_audio_frames=16,
        window=min(cfg.window, 8) if cfg.window else 0,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
