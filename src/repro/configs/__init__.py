"""Architecture registry.

Config files are named with the *exact* assigned architecture ids (which
contain dots and dashes, e.g. ``jamba-v0.1-52b.py``), so they are loaded via
importlib rather than as package modules.

    get_config("yi-6b")           -> full ModelConfig
    smoke_config("yi-6b")         -> reduced same-family config (CPU tests)
    input_specs(cfg, "train_4k")  -> ShapeDtypeStruct stand-ins for jit.lower
"""
from __future__ import annotations

import importlib.util
import os
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, shape_applicable, smoke_reduce
from repro.models.config import ModelConfig

_DIR = os.path.dirname(__file__)
_EXCLUDE = {"__init__.py", "base.py"}


def list_archs() -> List[str]:
    names = []
    for fn in sorted(os.listdir(_DIR)):
        if fn.endswith(".py") and fn not in _EXCLUDE:
            names.append(fn[:-3])
    return names


def _load(arch: str):
    path = os.path.join(_DIR, arch + ".py")
    if not os.path.exists(path):
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    spec = importlib.util.spec_from_file_location(
        "repro_config_" + arch.replace(".", "_").replace("-", "_"), path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    return smoke_reduce(get_config(arch))


def input_specs(cfg: ModelConfig, shape: str, batch: int | None = None,
                seq: int | None = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the given shape
    cell (weak-type-correct, shardable, no device allocation).

    Returns {"kind": train|prefill|decode, "batch": {...specs...},
             "seq": S, "global_batch": B}.
    """
    info = SHAPES[shape]
    b = batch or info["batch"]
    s = seq or info["seq"]
    kind = info["kind"]
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.enc_layers:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.num_audio_frames, cfg.d_model), dt)
        if cfg.cross_every and not cfg.enc_layers:
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), dt)
    else:  # decode: one new token against a seq-long cache
        specs["token"] = jax.ShapeDtypeStruct((b,), i32)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
    return {"kind": kind, "batch": specs, "seq": s, "global_batch": b}


def applicable(cfg: ModelConfig, shape: str):
    return shape_applicable(cfg, shape)


SHAPE_NAMES = list(SHAPES.keys())
