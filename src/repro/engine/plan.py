"""WalkPlan / WalkStats / WalkResult — the engine's declarative surface.

A :class:`WalkPlan` is the single description of *what* to walk (p/q/length/
mode/eps) and *how* (backend + layout/capacity knobs); :class:`WalkEngine`
turns it into an executable. ``WalkStats`` is the structured diagnostics
record the old call paths used to drop on the floor (dropped requests,
superstep count, collective-bytes estimate from ``repro.roofline``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

BACKENDS = ("reference", "sharded", "fused")


@dataclasses.dataclass(frozen=True)
class WalkPlan:
    """Frozen, hashable description of a walk workload.

    Layout knobs (``cap``/``hot_cap``) select the paper's FN variant:
    ``cap=None`` -> FN-Base (rows at max degree, no hot set);
    ``cap < max degree`` -> FN-Cache (popular rows replicated). ``mode``
    selects the sampling strategy (exact / approx / approx_always) and
    ``backend`` the execution substrate — the same plan runs bit-identically
    on all three backends (tested).
    """
    p: float = 1.0
    q: float = 1.0
    length: int = 80
    mode: str = "exact"               # exact | approx | approx_always
    approx_eps: float = 1e-3
    backend: str = "reference"        # reference | sharded | fused
    cap: Optional[int] = None         # cold row width (None -> FN-Base)
    hot_cap: Optional[int] = None     # hot row width (None -> max hot degree)
    capacity: Optional[object] = None  # sharded: request slots per
                                      # destination *per exchange* (pipelined
                                      # mode runs two half-size exchanges per
                                      # superstep). int, None (zero-drop
                                      # worst case), or "auto" (derived from
                                      # the cold degree mass —
                                      # ``roofline.traffic.
                                      # walk_auto_capacity``)
    strict_drops: bool = False        # raise (not warn) when requests drop
    pipeline: bool = False            # async superstep pipeline (DESIGN §12):
                                      # sharded -> double-buffered cohort
                                      # exchange overlapped with compute;
                                      # fused -> VMEM-persistent multi-step
                                      # kernel (exact + FN-Base layout, else
                                      # per-step kernel); reference -> no-op.
                                      # Walks are bit-identical either way.

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}")
        if self.length < 1:
            raise ValueError(f"length must be >= 1, got {self.length}")
        cap = self.capacity
        ok = cap is None or cap == "auto" or \
            (isinstance(cap, (int, np.integer)) and cap >= 1)
        if not ok:
            raise ValueError(
                f"capacity must be None, 'auto', or a positive int, "
                f"got {cap!r}")

    def params(self):
        """Legacy ``WalkParams`` view (for the deprecated shims)."""
        from repro.core.walk import WalkParams
        return WalkParams(p=self.p, q=self.q, length=self.length,
                          mode=self.mode, approx_eps=self.approx_eps)

    def sampler(self):
        from repro.engine.sampler import Sampler
        return Sampler(p=self.p, q=self.q, mode=self.mode,
                       eps=self.approx_eps, fused=self.backend == "fused")

    @staticmethod
    def from_params(params, **overrides) -> "WalkPlan":
        """Lift a legacy ``WalkParams`` into a plan (shim entry points)."""
        return WalkPlan(p=params.p, q=params.q, length=params.length,
                        mode=params.mode, approx_eps=params.approx_eps,
                        **overrides)


@dataclasses.dataclass(frozen=True)
class WalkStats:
    """Structured per-run diagnostics.

    ``dropped``            — NEIG requests beyond the static exchange
                             capacity (walker stayed put for that step);
                             always 0 on single-device backends.
    ``supersteps``         — Pregel supersteps executed (== walk length).
    ``collective_bytes``   — analytic per-device NEIG-exchange estimate from
                             ``repro.roofline.traffic`` (0 off-mesh); the
                             measured-from-HLO number comes from
                             ``WalkEngine.analyze()``.
    ``exposed_collective_bytes`` — the subset of ``collective_bytes`` that
                             sits on the superstep critical path (cannot
                             hide behind walker compute). Barrier mode:
                             equal to ``collective_bytes``. Pipelined mode:
                             strictly smaller (``roofline.traffic.
                             walk_overlap_model``).
    ``overlap_efficiency`` — ``1 - exposed/total`` collective bytes; 0 when
                             nothing is on the wire or nothing overlaps.
    ``graph_version``      — the GraphStore delta counter this run's walks
                             were sampled against (stamped at dispatch time,
                             so streamed rounds report the version they
                             actually walked); 0 without a store.
    ``delta_edges``        — cumulative edge add+remove events applied to
                             this engine via ``update()`` so far.
    ``invalidated_shard_fraction`` — fraction of shards whose device rows
                             the *last* ``update()`` rewrote (1.0 on a full
                             relayout, 0.0 before any update).
    """
    backend: str
    walkers: int
    supersteps: int
    dropped: int = 0
    collective_bytes: int = 0
    exposed_collective_bytes: int = 0
    overlap_efficiency: float = 0.0
    graph_version: int = 0
    delta_edges: int = 0
    invalidated_shard_fraction: float = 0.0


@dataclasses.dataclass(frozen=True)
class WalkResult:
    """Host-side walks [W, length] i32 plus their stats."""
    walks: np.ndarray
    stats: WalkStats
