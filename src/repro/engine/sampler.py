"""Shared 2nd-order sampling layer — the ``Sampler`` strategy (DESIGN.md §3).

Every walk backend draws the next step through this one implementation:

* ``repro.core.walk``             — single-device reference engine (vmap),
  which is also the **fused** backend: ``Sampler(fused=True)`` swaps the
  exact-slot computation for the Pallas kernel ``kernels.node2vec_step``
  (interpret mode off-TPU) with bit-identical results.
* ``repro.core.walk_distributed`` — shard_map engine; candidate rows arrive
  via the NEIG all_to_all instead of a local gather, but the sampling math is
  this module, not a copy.
* ``kernels/ref.py``              — the kernel's correctness oracle wraps
  :func:`exact_slots` directly, so the contract is written exactly once.

RNG contract (identical across backends, the bit-parity guarantee):
given the per-(walker, step) key ``k = fold_in(fold_in(seed, walker), step)``:

    k_exact, k_approx = split(k)
    r          = uniform(k_exact)                     # ONE uniform per walker
    slot_exact = count((cumsum(alpha * w) <= r * total) & valid)  # inv. CDF
    slot_alias = alias_sample(k_approx, ...)          # O(1) fast path

The count convention (count of cumsum entries <= target over valid lanes)
matches the Pallas kernel bit for bit; trailing pad lanes carry zero
probability so the draw is independent of the padded row width — FN-Base and
FN-Cache layouts, and all three backends, produce identical walks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.alias import alias_sample
from repro.core.graph import PAD_ID
from repro.core.transition import approx_gap, unnormalized_probs

MODES = ("exact", "approx", "approx_always")


def split_keys(keys: jax.Array):
    """Per-walker (k_exact, k_approx) from a [W]-batch of step keys."""
    k_exact = jax.vmap(lambda k: jax.random.split(k)[0])(keys)
    k_approx = jax.vmap(lambda k: jax.random.split(k)[1])(keys)
    return k_exact, k_approx


def exact_slots(cand_ids: jnp.ndarray, cand_w: jnp.ndarray, u: jnp.ndarray,
                prev_rows: jnp.ndarray, rand: jnp.ndarray, p: float,
                q: float) -> jnp.ndarray:
    """Batched exact 2nd-order draw — THE definition the Pallas kernel fuses.

    cand_ids/cand_w [W, D] (PAD_ID / 0 padded, rows sorted), u [W],
    prev_rows [W, Dp] (sorted N(u)), rand [W] uniforms in [0, 1).
    Returns the sampled candidate slot per walker, [W] i32.
    """
    probs = jax.vmap(
        lambda ci, cw, uu, pr: unnormalized_probs(ci, cw, uu, pr, p, q))(
            cand_ids, cand_w, u, prev_rows)
    cum = jnp.cumsum(probs, axis=-1)
    target = rand[:, None] * cum[:, -1:]
    valid = cand_ids != PAD_ID
    slot = jnp.sum(((cum <= target) & valid).astype(jnp.int32), axis=-1)
    return jnp.minimum(slot, cand_ids.shape[-1] - 1)


def first_order_slots(keys: jax.Array, alias_p: jnp.ndarray,
                      alias_i: jnp.ndarray, deg: jnp.ndarray) -> jnp.ndarray:
    """Step-0 / fast-path draw from static edge weights (Vose alias), [W]."""
    return jax.vmap(alias_sample)(keys, alias_p, alias_i, deg)


@dataclasses.dataclass(frozen=True)
class HotContext:
    """Per-walker inputs the approx fast path needs, layout-free.

    Both engines can supply these from their own storage (reference: the
    PaddedGraph lookups; sharded: the replicated hot pack) — values only
    matter where ``is_hot_v`` is true, so cold-walker lanes may carry
    anything gather-safe.
    """
    is_hot_v: jnp.ndarray   # [W] bool — current vertex is popular
    is_hot_u: jnp.ndarray   # [W] bool — previous vertex is popular
    deg_u: jnp.ndarray      # [W] i32  true degree of u
    deg_v: jnp.ndarray      # [W] i32  true degree of v (where hot)
    w_min_v: jnp.ndarray    # [W] f32
    w_max_v: jnp.ndarray    # [W] f32
    alias_p: jnp.ndarray    # [W, Da] 1st-order alias table rows of v
    alias_i: jnp.ndarray    # [W, Da]
    alias_deg: jnp.ndarray  # [W] live width of the alias tables (deg of v)


@dataclasses.dataclass(frozen=True)
class StepChoice:
    """Outcome of one superstep's sampling; the backend owns the id gather
    (layouts differ: the sharded approx_always path keeps candidates at cold
    width and reads hot ids from the replicated cache)."""
    slot_exact: jnp.ndarray
    slot_alias: Optional[jnp.ndarray] = None
    use_alias: Optional[jnp.ndarray] = None

    def slot(self) -> jnp.ndarray:
        """Combined slot for backends whose candidate rows cover both paths."""
        if self.use_alias is None:
            return self.slot_exact
        return jnp.where(self.use_alias, self.slot_alias, self.slot_exact)


@dataclasses.dataclass(frozen=True)
class Sampler:
    """2nd-order step strategy: exact / approx / approx_always.

    Frozen + hashable so it can ride through ``jax.jit`` as a static
    argument. ``fused=True`` computes the exact slot with the Pallas kernel
    (``kernels.ops.node2vec_step_op``, interpret mode off-TPU); the kernel
    implements :func:`exact_slots` verbatim, so results are bit-identical.
    """
    p: float = 1.0
    q: float = 1.0
    mode: str = "exact"
    eps: float = 1e-3
    fused: bool = False

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")

    def exact(self, rand, cand_ids, cand_w, u, prev_rows) -> jnp.ndarray:
        if self.fused:
            from repro.kernels.ops import node2vec_step_op
            return node2vec_step_op(cand_ids, cand_w, u, prev_rows, rand,
                                    self.p, self.q)
        return exact_slots(cand_ids, cand_w, u, prev_rows, rand, self.p,
                           self.q)

    def choose(self, keys, cand_ids, cand_w, u, prev_rows,
               hot: Optional[HotContext] = None) -> StepChoice:
        """One superstep draw for a [W]-batch of walkers."""
        k_exact, k_approx = split_keys(keys)
        rand = jax.vmap(jax.random.uniform)(k_exact)
        slot_exact = self.exact(rand, cand_ids, cand_w, u, prev_rows)
        if self.mode == "exact" or hot is None:
            return StepChoice(slot_exact)
        slot_alias = first_order_slots(k_approx, hot.alias_p, hot.alias_i,
                                       hot.alias_deg)
        if self.mode == "approx":
            gap = approx_gap(hot.deg_u, hot.deg_v, hot.w_min_v, hot.w_max_v,
                             self.p, self.q)
            use = hot.is_hot_v & (~hot.is_hot_u) & (gap < self.eps)
        else:  # approx_always — beyond-paper O(1) path at EVERY hot vertex
            use = hot.is_hot_v
        return StepChoice(slot_exact, slot_alias, use)
