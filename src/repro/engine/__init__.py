"""repro.engine — the single entry point for running Node2Vec walks.

    from repro.engine import WalkEngine, WalkPlan

    plan = WalkPlan(p=0.5, q=2.0, length=80, cap=32, backend="sharded")
    engine = WalkEngine.build(graph, plan, mesh=mesh)
    result = engine.run(seed=0)             # -> WalkResult(walks, stats)
    for r in engine.rounds(10, seed=0):     # FN-Multi streaming rounds
        train_on(r.walks)

Backends: ``reference`` (single-device jnp), ``sharded`` (shard_map over the
device mesh), ``fused`` (Pallas 2nd-order step kernel; interpret off-TPU).
All three share one sampling implementation (``repro.engine.sampler``) and
produce bit-identical walks from the same plan + seed (tested).

The legacy entry points ``core.walk.simulate_walks`` and
``core.walk_distributed.distributed_walks`` are deprecated shims over this
API (DESIGN.md §4).
"""
from repro.engine.plan import BACKENDS, WalkPlan, WalkResult, WalkStats
from repro.engine.sampler import Sampler

__all__ = ["BACKENDS", "Sampler", "WalkEngine", "WalkPlan", "WalkResult",
           "WalkStats", "round_seed"]


def __getattr__(name):
    # WalkEngine is resolved lazily: engine.engine imports the backend
    # modules, which themselves import repro.engine.sampler — eager import
    # here would make that a cycle.
    if name in ("WalkEngine", "round_seed"):
        from repro.engine import engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
