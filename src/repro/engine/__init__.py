"""repro.engine — the single entry point for running Node2Vec walks.

    from repro.engine import WalkEngine, WalkPlan

    plan = WalkPlan(p=0.5, q=2.0, length=80, cap=32, backend="sharded")
    engine = WalkEngine.build(graph, plan, mesh=mesh)
    result = engine.run(seed=0)             # -> WalkResult(walks, stats)
    for r in engine.rounds(10, seed=0):     # FN-Multi streaming rounds
        train_on(r.walks)

Backends: ``reference`` (single-device jnp), ``sharded`` (shard_map over the
device mesh), ``fused`` (Pallas 2nd-order step kernel; interpret off-TPU).
All three share one sampling implementation (``repro.engine.sampler``) and
produce bit-identical walks from the same plan + seed (tested).

Graphs churn: ``engine.update(deltas)`` applies a
``repro.data.DeltaBatch`` through the engine's ``GraphStore`` and patches
only the affected shards' device rows (``repro.engine.update``, DESIGN.md
§15), returning an :class:`~repro.engine.update.UpdateReport`. The legacy
``simulate_walks``/``distributed_walks`` shims (deprecated in PR 7) were
removed in PR 9.
"""
from repro.engine.plan import BACKENDS, WalkPlan, WalkResult, WalkStats
from repro.engine.sampler import Sampler

__all__ = ["BACKENDS", "Sampler", "UpdateReport", "WalkEngine", "WalkPlan",
           "WalkResult", "WalkStats", "round_seed"]


def __getattr__(name):
    # WalkEngine is resolved lazily: engine.engine imports the backend
    # modules, which themselves import repro.engine.sampler — eager import
    # here would make that a cycle.
    if name in ("WalkEngine", "round_seed"):
        from repro.engine import engine as _engine
        return getattr(_engine, name)
    if name == "UpdateReport":
        from repro.engine.update import UpdateReport
        return UpdateReport
    raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
