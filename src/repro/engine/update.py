"""Shard-local device updates for the walk engine (DESIGN.md §15).

The host side of an incremental update is the CSR patch
(``repro.data.deltas.apply_delta_csr``); this module is the device side:
given the patched CSR and the affected vertex set, recompute **only the
affected rows'** packed adjacency, alias tables, and (for FN-Cache) hot
cache entries, and splice them into the resident
:class:`~repro.core.graph.PaddedGraph` / ShardedGraph with functional
``.at[rows].set`` updates — unaffected shards' device buffers stay
resident, and the compiled walk fn is reused (row updates are data-only;
the jit signature bakes shapes, not values).

The patch falls back to a full **relayout** (fresh ``PaddedGraph.build`` /
``ShardedGraph.from_csr`` + fn rebuild) exactly when the static layout
can no longer represent the new graph bit-identically to a from-scratch
build at the same plan:

* hot-set **membership** changed (a vertex crossed ``deg > cap`` in either
  direction) — the replicated hot arrays' row set is a static shape;
* ``plan.cap is None`` (FN-Base) and the max degree grew past the frozen
  row width;
* ``plan.hot_cap is None`` and an affected hot vertex outgrew the frozen
  hot row width (a fresh build would widen it).

Row recomputation mirrors the from-scratch packers exactly (same CSR
slices, same ``build_alias_rows`` per row, same min/max masking), and
``build_alias_rows`` is row-independent — so a patched layout is
bit-identical to the from-scratch layout whenever no relayout was needed,
and walks are bit-identical in every case (property-tested on all three
backends).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.alias import build_alias_rows
from repro.core.graph import PAD_ID, CSRGraph, PaddedGraph
from repro.core.walk_distributed import ShardedGraph
from repro.data.deltas import PatchReport


@dataclasses.dataclass(frozen=True)
class UpdateReport:
    """What one ``WalkEngine.update`` / ``EmbeddingService.refresh`` did.

    ``invalidated_device_shards`` counts mesh shards whose row block was
    rewritten (all of them on relayout); ``hot_rows_updated`` counts
    replicated FN-Cache entries patched in place (these are replicated, so
    they rewrite one row on *every* shard but never force a relayout).
    """
    patch: PatchReport
    version: int
    relayout: bool
    device_shards: int
    invalidated_device_shards: int
    hot_rows_updated: int

    @property
    def invalidated_fraction(self) -> float:
        return self.invalidated_device_shards / max(self.device_shards, 1)


def _pack_rows(g: CSRGraph, vertices: np.ndarray, width: int):
    """CSR slices -> [len(vertices), width] padded rows (the packer shared
    by ``PaddedGraph.build`` and ``ShardedGraph.from_csr``, row-for-row)."""
    rows = np.full((len(vertices), width), PAD_ID, np.int32)
    wrows = np.zeros((len(vertices), width), np.float32)
    for i, v in enumerate(vertices.tolist()):
        lo, hi = int(g.row_ptr[v]), int(g.row_ptr[v + 1])
        d = min(hi - lo, width)
        rows[i, :d] = g.col[lo:lo + d]
        wrows[i, :d] = g.wgt[lo:lo + d]
    return rows, wrows


def _masked_min_max(adj: np.ndarray, wgt: np.ndarray, deg: np.ndarray):
    """Per-row min/max edge weight over live slots; 1.0 for isolated rows
    (mirrors the ``PaddedGraph.build`` convention bit-for-bit)."""
    w_min = np.ones(adj.shape[0], np.float32)
    w_max = np.ones(adj.shape[0], np.float32)
    nz = deg > 0
    mask = adj != PAD_ID
    with np.errstate(invalid="ignore"):
        w_min[nz] = np.where(mask, wgt, np.inf).min(axis=1)[nz]
        w_max[nz] = np.where(mask, wgt, -np.inf).max(axis=1)[nz]
    return w_min, w_max


def _pad_to_bucket(idx: np.ndarray, *arrs):
    """Pad a scatter's row indices (and per-row payloads) to the next power
    of two by repeating the last entry.

    The scatter's operand count is baked into its compiled shape, so
    un-bucketed ``.at[rows].set`` recompiles on every batch whose affected
    count differs — ~30ms per array, dwarfing the splice itself. Duplicate
    indices are safe for ``set`` because the duplicates carry identical
    values (any write order yields the same result)."""
    n = len(idx)
    b = 1 << max(0, n - 1).bit_length()
    if b == n:
        return (idx,) + arrs
    pad = b - n

    def rep(a):
        return np.concatenate([a, np.repeat(a[-1:], pad, axis=0)], axis=0)

    return (rep(idx),) + tuple(rep(a) for a in arrs)


def _needs_relayout(g: CSRGraph, affected: np.ndarray, was_hot: np.ndarray,
                    cap: int, hot_cap: int, plan_cap, plan_hot_cap) -> bool:
    deg_new = g.deg
    now_hot = deg_new[affected] > cap
    if np.any(was_hot != now_hot):
        return True
    if plan_cap is None and g.max_degree > cap:
        return True
    if plan_hot_cap is None and now_hot.any() \
            and int(deg_new[affected[now_hot]].max()) > hot_cap:
        return True
    return False


def patch_padded(pg: PaddedGraph, g: CSRGraph, affected: np.ndarray,
                 plan_cap, plan_hot_cap):
    """Splice the affected rows of the patched CSR into ``pg``.

    Returns ``(new_pg, relayout, hot_rows_updated)`` — ``new_pg`` shares
    every unaffected device buffer row with ``pg`` (functional update) or
    is a fresh ``PaddedGraph.build`` when a relayout was forced.
    """
    aff = np.asarray(affected, np.int64)
    if not aff.size:
        return pg, False, 0
    hot_pos_h = np.asarray(pg.hot_pos)
    was_hot = hot_pos_h[aff] >= 0
    if _needs_relayout(g, aff, was_hot, pg.cap, pg.hot_cap,
                       plan_cap, plan_hot_cap):
        return PaddedGraph.build(g, cap=plan_cap, hot_cap=plan_hot_cap), \
            True, 0

    deg_new = g.deg
    rows_adj, rows_wgt = _pack_rows(g, aff, pg.cap)
    ap, ai = build_alias_rows(rows_wgt)
    deg_aff = deg_new[aff]
    w_min_a, w_max_a = _masked_min_max(rows_adj, rows_wgt, deg_aff)

    hot_vs = aff[was_hot]
    hot_updates = 0
    h_pack = None
    if hot_vs.size:
        hpos = hot_pos_h[hot_vs]
        h_adj, h_wgt = _pack_rows(g, hot_vs, pg.hot_cap)
        h_ap, h_ai = build_alias_rows(h_wgt)
        # hot vertices' scalars come from the full-width hot row
        h_min, h_max = _masked_min_max(h_adj, h_wgt, deg_new[hot_vs])
        sel = np.searchsorted(aff, hot_vs)
        w_min_a[sel], w_max_a[sel] = h_min, h_max
        h_pack = (hpos, h_adj, h_wgt, h_ap, h_ai)
        hot_updates = int(hot_vs.size)

    aff_p, rows_adj, rows_wgt, ap, ai, deg_aff, w_min_a, w_max_a = \
        _pad_to_bucket(aff, rows_adj, rows_wgt, ap, ai, deg_aff,
                       w_min_a, w_max_a)
    rows = jnp.asarray(aff_p, jnp.int32)
    new = dataclasses.replace(
        pg,
        adj=pg.adj.at[rows].set(jnp.asarray(rows_adj)),
        wgt=pg.wgt.at[rows].set(jnp.asarray(rows_wgt)),
        alias_p=pg.alias_p.at[rows].set(jnp.asarray(ap)),
        alias_i=pg.alias_i.at[rows].set(jnp.asarray(ai)),
        deg=pg.deg.at[rows].set(jnp.asarray(deg_aff)),
        w_min=pg.w_min.at[rows].set(jnp.asarray(w_min_a)),
        w_max=pg.w_max.at[rows].set(jnp.asarray(w_max_a)))
    if h_pack is not None:
        hpos, h_adj, h_wgt, h_ap, h_ai = h_pack
        hpos, h_adj, h_wgt, h_ap, h_ai = _pad_to_bucket(
            hpos, h_adj, h_wgt, h_ap, h_ai)
        hrows = jnp.asarray(hpos, jnp.int32)
        new = dataclasses.replace(
            new,
            hot_adj=new.hot_adj.at[hrows].set(jnp.asarray(h_adj)),
            hot_wgt=new.hot_wgt.at[hrows].set(jnp.asarray(h_wgt)),
            hot_alias_p=new.hot_alias_p.at[hrows].set(jnp.asarray(h_ap)),
            hot_alias_i=new.hot_alias_i.at[hrows].set(jnp.asarray(h_ai)))
    return new, False, hot_updates


def patch_sharded(sg: ShardedGraph, g: CSRGraph, affected: np.ndarray,
                  plan_cap, plan_hot_cap):
    """Splice the affected rows into the resident sharded layout.

    Returns ``(new_sg, relayout, invalidated_shards, hot_rows_updated)``;
    ``invalidated_shards`` are the mesh shards whose row block changed
    (empty array + relayout=True means "rebuild everything"). The compiled
    walk fn takes the arrays as runtime args, so a non-relayout patch never
    recompiles.
    """
    aff = np.asarray(affected, np.int64)
    if not aff.size:
        return sg, False, np.zeros(0, np.int64), 0
    hot_ids_h = np.asarray(sg.hot_ids)
    real_hot = hot_ids_h.size > 0 and int(hot_ids_h[0]) != PAD_ID

    def hot_pos_of(vs):
        if not real_hot:
            return np.full(len(vs), -1, np.int64)
        pos = np.searchsorted(hot_ids_h, vs)
        pos = np.minimum(pos, len(hot_ids_h) - 1)
        return np.where(hot_ids_h[pos] == vs, pos, -1)

    was_hot = hot_pos_of(aff) >= 0
    if _needs_relayout(g, aff, was_hot, sg.cap, sg.hot_cap,
                       plan_cap, plan_hot_cap):
        return ShardedGraph.from_csr(g, sg.num_shards, cap=plan_cap,
                                     hot_cap=plan_hot_cap), \
            True, np.arange(sg.num_shards, dtype=np.int64), 0

    deg_new = g.deg
    rows_adj, rows_wgt = _pack_rows(g, aff, sg.cap)
    ap, ai = build_alias_rows(rows_wgt)
    deg_aff = deg_new[aff]

    aff_p, rows_adj, rows_wgt, ap, ai, deg_aff = _pad_to_bucket(
        aff, rows_adj, rows_wgt, ap, ai, deg_aff)
    rows = jnp.asarray(aff_p, jnp.int32)
    new = dataclasses.replace(
        sg,
        adj=sg.adj.at[rows].set(jnp.asarray(rows_adj)),
        wgt=sg.wgt.at[rows].set(jnp.asarray(rows_wgt)),
        alias_p=sg.alias_p.at[rows].set(jnp.asarray(ap)),
        alias_i=sg.alias_i.at[rows].set(jnp.asarray(ai)),
        deg=sg.deg.at[rows].set(jnp.asarray(deg_aff)))

    hot_updates = 0
    hot_vs = aff[was_hot]
    if hot_vs.size:
        hpos = hot_pos_of(hot_vs)
        h_adj, h_wgt = _pack_rows(g, hot_vs, sg.hot_cap)
        h_ap, h_ai = build_alias_rows(h_wgt)
        h_min, h_max = _masked_min_max(h_adj, h_wgt, deg_new[hot_vs])
        h_deg = deg_new[hot_vs]
        hpos, h_adj, h_wgt, h_ap, h_ai, h_min, h_max, h_deg = \
            _pad_to_bucket(hpos, h_adj, h_wgt, h_ap, h_ai, h_min, h_max,
                           h_deg)
        hrows = jnp.asarray(hpos, jnp.int32)
        new = dataclasses.replace(
            new,
            hot_adj=new.hot_adj.at[hrows].set(jnp.asarray(h_adj)),
            hot_wgt=new.hot_wgt.at[hrows].set(jnp.asarray(h_wgt)),
            hot_alias_p=new.hot_alias_p.at[hrows].set(jnp.asarray(h_ap)),
            hot_alias_i=new.hot_alias_i.at[hrows].set(jnp.asarray(h_ai)),
            hot_deg=new.hot_deg.at[hrows].set(jnp.asarray(h_deg)),
            hot_wmin=new.hot_wmin.at[hrows].set(jnp.asarray(h_min)),
            hot_wmax=new.hot_wmax.at[hrows].set(jnp.asarray(h_max)))
        hot_updates = int(hot_vs.size)
    elif not real_hot and (g.n - 1) in aff:
        # keep the no-hot sentinel's scalar lanes (a copy of row n-1, see
        # from_csr) in lockstep so patched == from_csr stays bit-exact;
        # these lanes are masked out of every sample and never affect walks
        lo = int(g.row_ptr[g.n - 1])
        d = min(int(g.row_ptr[g.n] - lo), sg.cap)
        w = g.wgt[lo:lo + d]
        wmin, wmax = (float(w.min()), float(w.max())) if d else (1.0, 1.0)
        new = dataclasses.replace(
            new,
            hot_deg=jnp.asarray(deg_new[g.n - 1:g.n]),
            hot_wmin=jnp.full((1,), wmin, jnp.float32),
            hot_wmax=jnp.full((1,), wmax, jnp.float32))

    invalidated = np.unique(aff // sg.n_local)
    return new, False, invalidated, hot_updates
