"""WalkEngine — one entry point over the reference, sharded, and fused
backends (DESIGN.md §3).

    engine = WalkEngine.build(graph, plan, mesh=None)
    result = engine.run(starts=None, seed=0)     # WalkResult(walks, stats)
    for r in engine.rounds(10, seed=0): ...      # FN-Multi streaming rounds

``build`` accepts a spec string / host :class:`CSRGraph` / ``Dataset`` /
:class:`~repro.data.store.GraphStore` (normalized through
``repro.data.open_graph``, and the engine keeps the store so
:meth:`WalkEngine.update` can apply edge deltas incrementally), a prebuilt
:class:`PaddedGraph`, or — for the sharded backend only — a
:class:`ShardedGraph`, which may be fully *abstract*
(``jax.ShapeDtypeStruct`` leaves) for compile-only roofline analysis via
:meth:`WalkEngine.analyze` (the dry-run path).

Walker identity: ``walker_ids`` default to the start vertex ids (the paper's
one-walk-per-vertex convention, and what the sharded partitioning requires),
so the same plan + seed gives bit-identical walks on every backend.
"""
from __future__ import annotations

import time
import warnings
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.graph import PaddedGraph
from repro.core.walk import run_fused_persistent, run_reference
from repro.core.walk_distributed import (ShardedGraph, make_distributed_walk)
from repro.engine.plan import WalkPlan, WalkResult, WalkStats
from repro.engine.update import UpdateReport, patch_padded, patch_sharded
from repro.launch.mesh import make_rw_mesh
from repro.roofline import analysis as roof
from repro.roofline.traffic import (walk_auto_capacity,
                                    walk_collective_bytes, walk_overlap_model)


def round_seed(seed: int, r: int) -> int:
    """Per-round seed for FN-Multi rounds (stable across engine versions —
    checkpointed runs resume bit-identically)."""
    return seed * 1000003 + r


class WalkEngine:
    """Executable walk workload: a plan bound to a graph (and mesh)."""

    def __init__(self, plan: WalkPlan, *, pg: Optional[PaddedGraph] = None,
                 sg: Optional[ShardedGraph] = None,
                 mesh: Optional[Mesh] = None, fn=None,
                 capacity: Optional[int] = None, store=None):
        self.plan = plan
        self.pg = pg
        self.sg = sg
        self.mesh = mesh
        self._fn = fn
        self.capacity = capacity
        self.store = store              # GraphStore (update() source of truth)
        self._sampler = plan.sampler()
        self._no_hot = pg is not None and \
            int(np.asarray(pg.hot_pos).max(initial=-1)) < 0
        self._delta_edges = 0           # cumulative churn via update()
        self._last_invalidated_fraction = 0.0

    # ------------------------------------------------------------- build --
    @classmethod
    def build(cls, graph, plan: WalkPlan,
              mesh: Optional[Mesh] = None) -> "WalkEngine":
        """Bind ``plan`` to ``graph``. ``mesh`` is only consulted by the
        sharded backend (default: a 1-D 'rw' mesh over all devices).

        ``graph`` may be anything ``repro.data.open_graph`` accepts — a
        spec string (``"wec:k=10,deg=30"``, ``"edgelist:/path.txt"``, ...),
        a host :class:`CSRGraph`, a ``Dataset``, or a ``GraphStore`` — in
        which case the engine holds the (possibly freshly opened) store and
        supports incremental :meth:`update`. Prebuilt device layouts
        (:class:`PaddedGraph`/:class:`ShardedGraph`) are also accepted but
        carry no store, so ``update()`` is unavailable. CSR input on the
        sharded backend takes the shard-by-shard ``ShardedGraph.from_csr``
        path: no dense whole-graph ``PaddedGraph`` intermediate."""
        store = None
        if not isinstance(graph, (PaddedGraph, ShardedGraph)):
            from repro.data import open_graph
            store = open_graph(graph)
            graph = store.graph
        if isinstance(graph, ShardedGraph) and plan.backend != "sharded":
            raise ValueError(
                f"ShardedGraph input requires backend='sharded', "
                f"got {plan.backend!r}")
        if plan.backend in ("reference", "fused"):
            pg = graph if isinstance(graph, PaddedGraph) else \
                PaddedGraph.build(graph, cap=plan.cap, hot_cap=plan.hot_cap)
            return cls(plan, pg=pg, store=store)

        rw = make_rw_mesh(mesh)
        num_shards = int(np.prod([rw.shape[a] for a in rw.axis_names]))
        pg = None
        if isinstance(graph, ShardedGraph):
            sg = graph
            if sg.num_shards != num_shards:
                raise ValueError(
                    f"ShardedGraph built for {sg.num_shards} shards but the "
                    f"mesh has {num_shards} devices")
        elif isinstance(graph, PaddedGraph):
            pg = graph
            sg = ShardedGraph.build(pg, num_shards)
        else:
            # CSRGraph: pack shard by shard, skipping the dense PaddedGraph
            sg = ShardedGraph.from_csr(graph, num_shards, cap=plan.cap,
                                       hot_cap=plan.hot_cap)
        # capacity default = one full walker block per destination: zero
        # drops, any skew. FN-Multi rounds are the lever for lowering it.
        # Pipelined mode exchanges per *cohort* (half blocks), so the
        # zero-drop default halves too — total bytes per superstep stay at
        # the barrier level while each exchange hides behind the other
        # cohort's compute.
        per_cohort = (sg.n_local + 1) // 2 if plan.pipeline else sg.n_local
        if plan.capacity == "auto":
            # derive from the cold degree mass: hot vertices are replicated
            # and never consume slots, so on skewed graphs the expected
            # per-destination demand is far below the worst case.
            if isinstance(sg.deg, jax.ShapeDtypeStruct):
                raise ValueError(
                    "capacity='auto' needs the concrete degree array; an "
                    "abstract ShardedGraph (analyze-only) must pass an "
                    "explicit capacity")
            capacity = walk_auto_capacity(
                np.asarray(sg.deg[:sg.n_orig]), cap=sg.cap,
                num_shards=sg.num_shards, walkers_per_shard=per_cohort)
        elif plan.capacity is not None:
            capacity = plan.capacity
        else:
            capacity = per_cohort
        fn = make_distributed_walk(sg, rw, plan.params(), capacity,
                                   length=plan.length,
                                   pipeline=plan.pipeline)
        return cls(plan, pg=pg, sg=sg, mesh=rw, fn=fn, capacity=capacity,
                   store=store)

    # --------------------------------------------------------------- run --
    @property
    def n(self) -> int:
        """Number of real (unpadded) vertices."""
        return self.sg.n_orig if self.sg is not None else self.pg.n

    def _abstract(self) -> bool:
        return self.sg is not None and isinstance(self.sg.adj,
                                                  jax.ShapeDtypeStruct)

    def _fused_persistent(self) -> bool:
        """Pipelined fused backend: the multi-superstep Pallas kernel that
        carries prev rows in VMEM is used when the layout lets it — exact
        sampling and FN-Base (no hot set; walks of length >= 2). Otherwise
        the per-step kernel path runs (bit-identical either way)."""
        return (self.plan.backend == "fused" and self.plan.pipeline
                and self._sampler.mode == "exact" and self.plan.length >= 2
                and self._no_hot)

    def _sharded_args(self, starts, walker_ids, key):
        g = self.sg
        return (g.adj, g.wgt, g.alias_p, g.alias_i, g.deg, g.hot_pack(),
                starts, walker_ids, key)

    def _update_meta(self):
        """(graph_version, delta_edges, invalidated fraction) snapshot —
        taken at *dispatch* time so streamed rounds report the graph state
        they actually walked, not the one current at finalize."""
        gv = self.store.version if self.store is not None else 0
        return (gv, self._delta_edges, self._last_invalidated_fraction)

    def _dispatch(self, starts, seed: int, walker_ids):
        """Launch one run asynchronously; returns
        (walks, drops, slice_to, update_meta)."""
        key = jax.random.PRNGKey(seed)
        if self.plan.backend in ("reference", "fused"):
            if starts is None:
                starts = np.arange(self.pg.n, dtype=np.int32)
            starts = jnp.asarray(starts, jnp.int32)
            walker_ids = starts if walker_ids is None else \
                jnp.asarray(walker_ids, jnp.int32)
            if self._fused_persistent():
                walks = run_fused_persistent(self.pg, starts, walker_ids,
                                             key, self._sampler,
                                             self.plan.length)
            else:
                walks = run_reference(self.pg, starts, walker_ids, key,
                                      self._sampler, self.plan.length)
            return walks, None, None, self._update_meta()

        if self._abstract():
            raise ValueError("engine was built from an abstract ShardedGraph"
                             " — only analyze() is available")
        slice_to = None
        if starts is None:
            starts = np.arange(self.sg.n, dtype=np.int32)
            slice_to = self.sg.n_orig   # padding vertices walk self-loops
        starts = np.asarray(starts, np.int32)
        if starts.shape[0] % self.sg.num_shards:
            raise ValueError(
                f"walker count {starts.shape[0]} must divide evenly over "
                f"{self.sg.num_shards} shards")
        # walkers are co-located with their start vertex: walker block s gets
        # starts[s*W:(s+1)*W] and reads the start row locally, so each start
        # must live on the shard its position lands on (else the first step
        # would silently clamp to a wrong local row).
        w_local = starts.shape[0] // self.sg.num_shards
        owner = starts // self.sg.n_local
        placed = np.arange(starts.shape[0]) // w_local
        if not np.array_equal(owner, placed):
            bad = int(np.nonzero(owner != placed)[0][0])
            raise ValueError(
                f"starts must be grouped by owning shard (vertex id // "
                f"{self.sg.n_local}): starts[{bad}]={int(starts[bad])} "
                f"belongs to shard {int(owner[bad])} but is placed on shard "
                f"{int(placed[bad])}")
        walker_ids = starts if walker_ids is None else \
            np.asarray(walker_ids, np.int32)
        walks, drops = self._fn(*self._sharded_args(
            jnp.asarray(starts), jnp.asarray(walker_ids), key))
        return walks, drops, slice_to, self._update_meta()

    def _finalize(self, dispatched) -> WalkResult:
        walks, drops, slice_to, update_meta = dispatched
        walks = np.asarray(walks)
        if slice_to is not None:
            walks = walks[:slice_to]
        dropped = int(drops) if drops is not None else 0
        if dropped:
            msg = (f"{dropped} NEIG requests dropped (capacity="
                   f"{self.capacity}); affected walkers stayed put for those"
                   f" steps — raise WalkPlan.capacity or walk fewer vertices"
                   f" per round (FN-Multi)")
            if self.plan.strict_drops:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
        overlap = self._overlap_estimate(int(walks.shape[0]))
        gv, delta_edges, inv_frac = update_meta
        stats = WalkStats(
            backend=self.plan.backend, walkers=int(walks.shape[0]),
            supersteps=self.plan.length, dropped=dropped,
            collective_bytes=overlap["total_bytes"],
            exposed_collective_bytes=overlap["exposed_bytes"],
            overlap_efficiency=overlap["efficiency"],
            graph_version=gv, delta_edges=delta_edges,
            invalidated_shard_fraction=inv_frac)
        return WalkResult(walks=walks, stats=stats)

    def _collective_estimate(self) -> int:
        if self.sg is None:
            return 0
        w_bytes = np.dtype(self.sg.wgt.dtype).itemsize
        return walk_collective_bytes(self.sg.num_shards, self.capacity,
                                     self.sg.cap, self.plan.length,
                                     w_bytes=w_bytes)

    def _overlap_estimate(self, walkers: int) -> dict:
        """Analytic total/exposed collective bytes for a run of ``walkers``
        walkers (``roofline.traffic.walk_overlap_model``)."""
        if self.sg is None:
            return {"total_bytes": 0, "exposed_bytes": 0, "efficiency": 0.0}
        g = self.sg
        w_bytes = np.dtype(g.wgt.dtype).itemsize
        width = g.cap if self._sampler.mode == "approx_always" else g.hot_cap
        return walk_overlap_model(
            g.num_shards, self.capacity, g.cap, self.plan.length,
            walkers_per_shard=max(walkers // g.num_shards, 1),
            pipeline=self.plan.pipeline and self.plan.length >= 2,
            w_bytes=w_bytes, width=width)

    def run(self, starts=None, seed: int = 0, walker_ids=None) -> WalkResult:
        """Walk ``starts`` (default: every vertex) with the bound plan."""
        return self._finalize(self._dispatch(starts, seed, walker_ids))

    def rounds(self, num_rounds: int, seed: int = 0,
               start: int = 0) -> Iterator[WalkResult]:
        """FN-Multi streaming rounds: round ``k+1`` is *dispatched* (async
        jax execution) before round ``k`` is finalized and yielded, so the
        consumer (SGNS training) overlaps with the next round's walk."""
        if num_rounds <= start:
            return
        pending = self._dispatch(None, round_seed(seed, start), None)
        for r in range(start, num_rounds):
            nxt = self._dispatch(None, round_seed(seed, r + 1), None) \
                if r + 1 < num_rounds else None
            yield self._finalize(pending)
            pending = nxt

    # ------------------------------------------------------------ update --
    def update(self, deltas) -> UpdateReport:
        """Apply edge deltas to the resident graph *without* a whole-graph
        rebuild: the store patches the host CSR shard-locally, then only the
        affected rows' packed adjacency / alias tables / FN-Cache hot
        entries are spliced into the device layout. Unaffected shards'
        buffers stay resident and the compiled walk fn is reused; a full
        relayout (fresh layout + fn) happens only when the static shapes
        can no longer represent the new graph (see ``repro.engine.update``).

        Frozen across updates (bounded staleness, reopen/rebuild to refresh):
        the exchange ``capacity`` (plan ``"auto"`` is derived once at build)
        and, under ``relabel=degree``, the degree ranking. Walks after
        ``update()`` are bit-identical to a from-scratch engine at the same
        store version (property-tested on all three backends).
        """
        if self.store is None:
            raise ValueError(
                "update() needs the engine's GraphStore — build the engine "
                "from a spec string, CSRGraph, Dataset, or GraphStore (a "
                "prebuilt PaddedGraph/ShardedGraph carries no host CSR to "
                "patch)")
        if self._abstract():
            raise ValueError("engine was built from an abstract ShardedGraph"
                             " — only analyze() is available")
        patch = self.store.apply(deltas)
        g = self.store.graph
        aff = patch.affected
        if self.plan.backend in ("reference", "fused"):
            self.pg, relayout, hot_rows = patch_padded(
                self.pg, g, aff, self.plan.cap, self.plan.hot_cap)
            if relayout:
                self._no_hot = \
                    int(np.asarray(self.pg.hot_pos).max(initial=-1)) < 0
            device_shards = patch.num_shards
            invalidated = device_shards if relayout \
                else int(len(patch.affected_shards))
        else:
            self.sg, relayout, inv_shards, hot_rows = patch_sharded(
                self.sg, g, aff, self.plan.cap, self.plan.hot_cap)
            if relayout:
                # shapes may have changed (cap / hot set size) -> fresh fn;
                # capacity stays frozen so the exchange shapes are stable
                self._fn = make_distributed_walk(
                    self.sg, self.mesh, self.plan.params(), self.capacity,
                    length=self.plan.length, pipeline=self.plan.pipeline)
            device_shards = self.sg.num_shards
            invalidated = int(len(inv_shards))
        self._delta_edges += patch.delta_edges
        self._last_invalidated_fraction = invalidated / max(device_shards, 1)
        return UpdateReport(
            patch=patch, version=self.store.version, relayout=relayout,
            device_shards=device_shards,
            invalidated_device_shards=invalidated,
            hot_rows_updated=hot_rows)

    # ----------------------------------------------------------- analyze --
    def analyze(self, num_walkers: Optional[int] = None) -> dict:
        """Compile-only roofline measurement for the sharded backend: lower +
        compile the walk (works with an abstract ShardedGraph), then read
        FLOPs from ``cost_analysis`` and collective bytes from the optimized
        HLO. The superstep loop lowers to a ``while`` whose body appears once
        in the HLO, and cost_analysis does not multiply through while loops
        either (verified) — so the numbers are already per-superstep (plus a
        small step-0 constant outside the loop)."""
        if self.sg is None:
            raise ValueError("analyze() requires the sharded backend")
        g = self.sg
        if num_walkers is None:
            num_walkers = g.n
        starts = jax.ShapeDtypeStruct((num_walkers,), jnp.int32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        t0 = time.time()
        lowered = self._fn.lower(*self._sharded_args(starts, starts, key))
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ca = roof.cost_dict(compiled.cost_analysis())
        coll = roof.collective_bytes(compiled.as_text())
        counts = coll.pop("_counts")
        flops_step = float(ca.get("flops", 0.0))
        coll_total = float(sum(coll.values()))
        try:
            arg_bytes = compiled.memory_analysis().argument_size_in_bytes
        except Exception:
            arg_bytes = None
        graph_bytes = sum(
            int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
            for x in (g.adj, g.wgt, g.alias_p, g.alias_i)) // g.num_shards \
            + sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
                  for x in g.hot_pack())
        overlap = self._overlap_estimate(num_walkers)
        return {
            "backend": self.plan.backend, "mode": self.plan.mode,
            "pipeline": self.plan.pipeline,
            "overlap_total_bytes": overlap["total_bytes"],
            "overlap_exposed_bytes": overlap["exposed_bytes"],
            "overlap_efficiency": overlap["efficiency"],
            "cap": g.cap, "hot_cap": g.hot_cap, "capacity": self.capacity,
            "shards": g.num_shards, "n": g.n,
            "walkers_per_shard": num_walkers // g.num_shards,
            "compile_seconds": t_compile,
            "flops_per_step_per_dev": flops_step,
            "coll_bytes_per_step_per_dev": coll_total,
            "coll_by_op_per_step": dict(coll),
            "coll_counts": counts,
            "t_compute": flops_step / roof.PEAK_FLOPS,
            "t_collective": coll_total / roof.LINK_BW,
            "analytic_coll_bytes_per_dev": self._collective_estimate(),
            "graph_bytes_per_dev": int(graph_bytes),
            "argument_bytes_per_dev": arg_bytes,
        }
