"""Pallas TPU kernel: causal flash attention (forward).

Addresses the dominant HBM-traffic term of the prefill/train cells
(roofline/traffic.py ``attn_s2``): unfused attention writes+reads the
[B, H, S, S] score/prob tensors (~12 bytes per element); the flash form keeps
a [BQ, BK] tile in VMEM with an online-softmax running (max, denom), so HBM
traffic collapses to one read of q/k/v and one write of o.

Tiling: grid (B*H, S/BQ). For each q block, an inner ``fori_loop`` streams
k/v blocks up to the causal frontier; the [BQ, BK] logits tile lives entirely
in VMEM. Supports causal masking and sliding windows (mixtral SWA).

Layout contract (ops.py pads/reshapes from the model's [B, S, H, dh]):
  q   [BH, S, dh]   (GQA: kv already expanded to H by the wrapper)
  k   [BH, S, dh]
  v   [BH, S, dh]
  out [BH, S, dh]
S % BQ == 0, dh % 128 == 0 (pad), BQ == BK.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block: int, seq: int,
                  window: int, causal: bool, sm_scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                 # [BQ, dh]
    q = q * sm_scale

    m0 = jnp.full((block, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block, 1), jnp.float32)
    acc0 = jnp.zeros((block, q.shape[-1]), jnp.float32)

    q_start = qi * block
    # causal frontier: only k blocks with start <= q_end participate
    num_kb = seq // block
    last_kb = jnp.minimum(((q_start + block - 1) // block) + 1,
                          num_kb) if causal else num_kb
    # sliding window lower bound
    first_kb = (jnp.maximum((q_start - window + 1) // block, 0)
                if window else 0)

    def body(kb, carry):
        m, l, acc = carry
        k_start = kb * block
        k = k_ref[pl.dslice(k_start, block), :].astype(jnp.float32)
        v = v_ref[pl.dslice(k_start, block), :].astype(jnp.float32)
        s = q @ k.T                                    # [BQ, BK]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block, block),
                                                  0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block, block),
                                                  1)
        ok = jnp.ones((block, block), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(first_kb, last_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "window", "causal",
                                             "interpret", "sm_scale"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    block: int = 128, window: int = 0, causal: bool = True,
                    interpret: bool = False,
                    sm_scale: float | None = None) -> jnp.ndarray:
    """q/k/v [BH, S, dh] -> out [BH, S, dh]. Pass ``sm_scale`` when dh is
    padded (the scale must use the TRUE head dim)."""
    bh, s, dh = q.shape
    assert s % block == 0 and dh % 128 == 0, (s, dh)
    grid = (bh, s // block)
    if sm_scale is None:
        sm_scale = dh ** -0.5
    kernel = functools.partial(_flash_kernel, block=block, seq=s,
                               window=window, causal=causal,
                               sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, s, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
