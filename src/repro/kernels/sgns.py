"""Pallas TPU kernel: fused SGNS forward + backward.

For a batch block of gathered embedding rows, computes the skip-gram
negative-sampling loss AND all three gradients in one VMEM-resident pass:

    s_p = sigmoid(ci.po)            g_po  = (s_p - 1) * ci
    s_nk = sigmoid(ci.no_k)         g_nok = s_nk * ci
    loss = -log s_p - sum_k log(1 - s_nk)
    g_ci = (s_p - 1) * po + sum_k s_nk * no_k

The jnp autodiff path materializes the [B, K, D] products twice (fwd + bwd);
the fused kernel reads ci/po/no exactly once and writes the three grads once —
the arithmetic-intensity floor for this op. Embedding dim D is the lane axis
(multiple of 128); negatives K is unrolled (small, e.g. 5-8).

Shapes: ci, po [B, D] f32; no [B, K, D] f32; valid [B] f32 mask.
Out: loss_sum [1, 1] (masked sum), g_ci, g_po [B, D], g_no [B, K, D].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _sgns_kernel(ci_ref, po_ref, no_ref, valid_ref, loss_ref, gci_ref,
                 gpo_ref, gno_ref):
    i = pl.program_id(0)
    ci = ci_ref[...]              # [B, D]
    po = po_ref[...]              # [B, D]
    no = no_ref[...]              # [B, K, D]
    valid = valid_ref[...]        # [B, 1]

    pos_score = jnp.sum(ci * po, axis=-1, keepdims=True)       # [B, 1]
    s_p = _sigmoid(pos_score)
    neg_score = jnp.sum(no * ci[:, None, :], axis=-1)          # [B, K]
    s_n = _sigmoid(neg_score)

    # loss = -log s_p - sum log(1 - s_n) = softplus(-x_p) + sum softplus(x_n)
    loss = (jnp.logaddexp(0.0, -pos_score[:, 0]) +
            jnp.sum(jnp.logaddexp(0.0, neg_score), axis=-1))   # [B]
    masked = loss * valid[:, 0]

    @pl.when(i == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)

    loss_ref[0, 0] += jnp.sum(masked)

    coeff_p = (s_p - 1.0) * valid                              # [B, 1]
    coeff_n = s_n * valid                                      # [B, K]
    gpo_ref[...] = coeff_p * ci
    gno_ref[...] = coeff_n[:, :, None] * ci[:, None, :]
    gci_ref[...] = coeff_p * po + jnp.sum(coeff_n[:, :, None] * no, axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_fused(ci: jnp.ndarray, po: jnp.ndarray, no: jnp.ndarray,
               valid: jnp.ndarray, block_b: int = 512,
               interpret: bool = False):
    """Fused SGNS loss+grads. B % block_b == 0, D % 128 == 0 required
    (ops.py pads)."""
    b, d = ci.shape
    k = no.shape[1]
    assert d % LANE == 0 and b % block_b == 0, (b, d)
    grid = (b // block_b,)

    out = pl.pallas_call(
        _sgns_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, k, d), jnp.float32),
        ],
        interpret=interpret,
    )(ci, po, no, valid.reshape(b, 1))
    loss_sum, g_ci, g_po, g_no = out
    return loss_sum[0, 0], g_ci, g_po, g_no


def sgns_row_grads(ci, po, no, valid, backend: str = "jnp"):
    """Loss (masked *sum*) + per-row gradients for gathered SGNS rows.

    The row-level counterpart of ``repro.core.skipgram.sgns_grads``: no
    table scatter — the caller owns where the rows live (the sharded trainer
    scatters them onto per-shard unique-row sets). ``backend="fused"`` runs
    the Pallas kernel above; ``backend="jnp"`` is the same closed form the
    kernel computes, kept here next to it so the two cannot drift.

    ci, po: [B, D]; no: [B, K, D]; valid: [B] f32.
    Returns (loss_sum, g_ci [B, D], g_po [B, D], g_no [B, K, D]).
    """
    if backend == "fused":
        from repro.kernels.ops import sgns_fused_op
        return sgns_fused_op(ci, po, no, valid)
    if backend != "jnp":
        raise ValueError(f"sgns backend must be jnp|fused, got {backend!r}")
    pos_score = jnp.sum(ci * po, axis=-1, keepdims=True)       # [B, 1]
    s_p = _sigmoid(pos_score)
    neg_score = jnp.sum(no * ci[:, None, :], axis=-1)          # [B, K]
    s_n = _sigmoid(neg_score)
    loss = (jnp.logaddexp(0.0, -pos_score[:, 0]) +
            jnp.sum(jnp.logaddexp(0.0, neg_score), axis=-1))   # [B]
    loss_sum = jnp.sum(loss * valid)
    coeff_p = (s_p - 1.0) * valid[:, None]                     # [B, 1]
    coeff_n = s_n * valid[:, None]                             # [B, K]
    g_po = coeff_p * ci
    g_no = coeff_n[:, :, None] * ci[:, None, :]
    g_ci = coeff_p * po + jnp.sum(coeff_n[:, :, None] * no, axis=1)
    return loss_sum, g_ci, g_po, g_no
