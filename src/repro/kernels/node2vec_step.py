"""Pallas TPU kernel: fused Node2Vec 2nd-order step.

One kernel fuses the per-walker hot loop of the walk engine:

    membership  x in N(u)        (streamed equality reduction over N(u))
    alpha_pq    {1/p, 1, 1/q}    (select)
    probs       alpha * w        (VPU)
    sampling    inverse-CDF      (cumsum + compare-count, one uniform/walker)

The unfused jnp path materializes membership, alpha, probs and the cumsum as
separate HBM tensors ([W, D] each); fusing keeps everything for a walker block
resident in VMEM — the step becomes memory-bound on exactly one read of the
candidate/prev rows, which is the roofline floor for this op.

Tiling: grid over walker blocks (BW rows); the candidate row block
[BW, D] lives in VMEM, and the membership reduction streams N(u) in LANE-wide
chunks so the peak VMEM working set is [BW, D] + [BW, D, LANE] bools per
chunk iteration (bounded, independent of DP).

Layout contract (matches the walk engines):
  cand_ids  [W, D]  i32, PAD_ID padded, row-sorted
  cand_w    [W, D]  f32, 0 padded
  u         [W]     i32 (previous vertex)
  prev_ids  [W, DP] i32, sorted, PAD_ID padded (N(u))
  rand      [W]     f32 uniform in [0, 1)
Returns
  slot      [W]     i32 sampled candidate slot (caller maps to id)

p, q are compile-time constants (walk hyper-parameters), baked into the
kernel body — no scalar operands needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import PAD_ID

LANE = 128


def _step_kernel(cand_ids_ref, cand_w_ref, u_ref, prev_ref, rand_ref,
                 slot_ref, *, p_inv: float, q_inv: float):
    cand = cand_ids_ref[...]          # [BW, D] i32
    w = cand_w_ref[...]               # [BW, D] f32
    u = u_ref[...]                    # [BW, 1] i32
    r = rand_ref[...]                 # [BW, 1] f32

    dp = prev_ref.shape[-1]
    member = jnp.zeros(cand.shape, jnp.bool_)

    def body(k, member):
        chunk = prev_ref[:, pl.dslice(k * LANE, LANE)]   # [BW, LANE]
        eq = cand[:, :, None] == chunk[:, None, :]       # [BW, D, LANE]
        return member | jnp.any(eq, axis=-1)

    member = jax.lax.fori_loop(0, dp // LANE, body, member)

    is_u = cand == u                              # [BW, D]
    valid = cand != PAD_ID
    alpha = jnp.where(is_u, p_inv, jnp.where(member, 1.0, q_inv))
    probs = jnp.where(valid, alpha * w, 0.0)      # [BW, D]
    cum = jnp.cumsum(probs, axis=-1)
    total = cum[:, -1:]
    target = r * total
    # index of first cumsum entry > target == count of entries <= target
    slot = jnp.sum(((cum <= target) & valid).astype(jnp.int32), axis=-1,
                   keepdims=True)
    slot = jnp.minimum(slot, cand.shape[-1] - 1)
    slot_ref[...] = slot.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("p", "q", "block_w", "interpret"))
def node2vec_step(cand_ids: jnp.ndarray, cand_w: jnp.ndarray, u: jnp.ndarray,
                  prev_ids: jnp.ndarray, rand: jnp.ndarray, p: float,
                  q: float, block_w: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """Fused step over all walkers. D/DP must be multiples of 128 and W a
    multiple of block_w (ops.py pads arbitrary shapes to this contract)."""
    wk, d = cand_ids.shape
    dp = prev_ids.shape[-1]
    assert d % LANE == 0 and dp % LANE == 0, (d, dp)
    assert wk % block_w == 0, (wk, block_w)
    grid = (wk // block_w,)
    kernel = functools.partial(_step_kernel, p_inv=1.0 / p, q_inv=1.0 / q)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w, d), lambda i: (i, 0)),
            pl.BlockSpec((block_w, d), lambda i: (i, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_w, dp), lambda i: (i, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wk, 1), jnp.int32),
        interpret=interpret,
    )(cand_ids, cand_w, u.reshape(wk, 1), prev_ids, rand.reshape(wk, 1))
    return out[:, 0]
