"""Pallas TPU kernel: fused Node2Vec 2nd-order step.

One kernel fuses the per-walker hot loop of the walk engine:

    membership  x in N(u)        (streamed equality reduction over N(u))
    alpha_pq    {1/p, 1, 1/q}    (select)
    probs       alpha * w        (VPU)
    sampling    inverse-CDF      (cumsum + compare-count, one uniform/walker)

The unfused jnp path materializes membership, alpha, probs and the cumsum as
separate HBM tensors ([W, D] each); fusing keeps everything for a walker block
resident in VMEM — the step becomes memory-bound on exactly one read of the
candidate/prev rows, which is the roofline floor for this op.

Tiling: grid over walker blocks (BW rows); the candidate row block
[BW, D] lives in VMEM, and the membership reduction streams N(u) in LANE-wide
chunks so the peak VMEM working set is [BW, D] + [BW, D, LANE] bools per
chunk iteration (bounded, independent of DP).

Layout contract (matches the walk engines):
  cand_ids  [W, D]  i32, PAD_ID padded, row-sorted
  cand_w    [W, D]  f32, 0 padded
  u         [W]     i32 (previous vertex)
  prev_ids  [W, DP] i32, sorted, PAD_ID padded (N(u))
  rand      [W]     f32 uniform in [0, 1)
Returns
  slot      [W]     i32 sampled candidate slot (caller maps to id)

p, q are compile-time constants (walk hyper-parameters), baked into the
kernel body — no scalar operands needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.graph import PAD_ID

LANE = 128


def _step_kernel(cand_ids_ref, cand_w_ref, u_ref, prev_ref, rand_ref,
                 slot_ref, *, p_inv: float, q_inv: float):
    cand = cand_ids_ref[...]          # [BW, D] i32
    w = cand_w_ref[...]               # [BW, D] f32
    u = u_ref[...]                    # [BW, 1] i32
    r = rand_ref[...]                 # [BW, 1] f32

    dp = prev_ref.shape[-1]
    member = jnp.zeros(cand.shape, jnp.bool_)

    def body(k, member):
        chunk = prev_ref[:, pl.dslice(k * LANE, LANE)]   # [BW, LANE]
        eq = cand[:, :, None] == chunk[:, None, :]       # [BW, D, LANE]
        return member | jnp.any(eq, axis=-1)

    member = jax.lax.fori_loop(0, dp // LANE, body, member)

    is_u = cand == u                              # [BW, D]
    valid = cand != PAD_ID
    alpha = jnp.where(is_u, p_inv, jnp.where(member, 1.0, q_inv))
    probs = jnp.where(valid, alpha * w, 0.0)      # [BW, D]
    cum = jnp.cumsum(probs, axis=-1)
    total = cum[:, -1:]
    target = r * total
    # index of first cumsum entry > target == count of entries <= target
    slot = jnp.sum(((cum <= target) & valid).astype(jnp.int32), axis=-1,
                   keepdims=True)
    slot = jnp.minimum(slot, cand.shape[-1] - 1)
    slot_ref[...] = slot.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("p", "q", "block_w", "interpret"))
def node2vec_step(cand_ids: jnp.ndarray, cand_w: jnp.ndarray, u: jnp.ndarray,
                  prev_ids: jnp.ndarray, rand: jnp.ndarray, p: float,
                  q: float, block_w: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """Fused step over all walkers. D/DP must be multiples of 128 and W a
    multiple of block_w (ops.py pads arbitrary shapes to this contract)."""
    wk, d = cand_ids.shape
    dp = prev_ids.shape[-1]
    assert d % LANE == 0 and dp % LANE == 0, (d, dp)
    assert wk % block_w == 0, (wk, block_w)
    grid = (wk // block_w,)
    kernel = functools.partial(_step_kernel, p_inv=1.0 / p, q_inv=1.0 / q)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w, d), lambda i: (i, 0)),
            pl.BlockSpec((block_w, d), lambda i: (i, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_w, dp), lambda i: (i, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wk, 1), jnp.int32),
        interpret=interpret,
    )(cand_ids, cand_w, u.reshape(wk, 1), prev_ids, rand.reshape(wk, 1))
    return out[:, 0]


# ---------------------------------------------------------------------------
# Multi-superstep persistent-walk kernel (WalkPlan.pipeline, fused backend)
# ---------------------------------------------------------------------------
#
# The per-step kernel above re-reads the [BW, DP] prev-row block from HBM on
# every superstep even though it is exactly the previous step's candidate
# block, which was already resident in VMEM when that step ran. This kernel
# runs the *whole* second-order walk for a walker block inside one
# pallas_call: the prev rows live in a VMEM scratch buffer that is written
# once per superstep (from the candidate block that is in VMEM anyway) and
# never round-trips through HBM. Per superstep the only HBM traffic is the
# candidate-row gather from the graph and one [BW] column of the output.
#
# Scope: exact sampling on the FN-Base layout (cap == max degree, empty hot
# set) — the hot-cache/approx paths keep using the per-step kernel. Step 0
# (the first-order alias draw) happens on the host; the kernel runs steps
# 1..length-1 with host-precomputed uniforms (the RNG is a pure function of
# (walker, step), so walks stay bit-identical to the reference backend).
#
# TPU caveat: the candidate gather is a dynamic row gather from the graph
# block; on real hardware the graph block must fit VMEM (small/medium graphs
# or a per-shard slice) — this container is interpret-only, where the gather
# is exact but unprofiled.


def _walk_kernel(adj_ref, wgt_ref, deg_ref, u0_ref, v1_ref, rand_ref,
                 out_ref, prev_scratch, *, p_inv: float, q_inv: float,
                 length: int):
    adj = adj_ref[...]                # [n, D] i32 (graph block, VMEM)
    wgt = wgt_ref[...]                # [n, D] f32
    deg = deg_ref[...][:, 0]          # [n]    i32
    # prev rows for step 1 = N(u0): gathered once, then carried in VMEM
    prev_scratch[...] = jnp.take(adj, u0_ref[...][:, 0], axis=0)

    def body(s, carry):
        u, v = carry                                  # [BW] each
        cand = jnp.take(adj, v, axis=0)               # [BW, D]
        w = jnp.take(wgt, v, axis=0)

        # membership vs the VMEM-carried prev rows, LANE-chunked (same
        # bounded working set as the per-step kernel)
        def mem_body(k, member):
            chunk = prev_scratch[:, pl.dslice(k * LANE, LANE)]
            eq = cand[:, :, None] == chunk[:, None, :]
            return member | jnp.any(eq, axis=-1)

        member = jax.lax.fori_loop(0, cand.shape[-1] // LANE, mem_body,
                                   jnp.zeros(cand.shape, jnp.bool_))
        is_u = cand == u[:, None]
        valid = cand != PAD_ID
        alpha = jnp.where(is_u, p_inv, jnp.where(member, 1.0, q_inv))
        probs = jnp.where(valid, alpha * w, 0.0)
        cum = jnp.cumsum(probs, axis=-1)
        target = rand_ref[:, pl.dslice(s, 1)] * cum[:, -1:]
        slot = jnp.sum(((cum <= target) & valid).astype(jnp.int32), axis=-1)
        slot = jnp.minimum(slot, cand.shape[-1] - 1)
        nxt = jnp.take_along_axis(cand, slot[:, None], axis=1)[:, 0]
        nxt = jnp.where(jnp.take(deg, v) > 0, nxt, v)  # dead end: stay
        prev_scratch[...] = cand                       # N(v) for step s+2
        out_ref[:, pl.dslice(s, 1)] = nxt[:, None]
        return v, nxt

    jax.lax.fori_loop(0, length - 1, body, (u0_ref[...][:, 0],
                                            v1_ref[...][:, 0]))


@functools.partial(jax.jit,
                   static_argnames=("p", "q", "block_w", "interpret"))
def node2vec_walk(adj: jnp.ndarray, wgt: jnp.ndarray, deg: jnp.ndarray,
                  u0: jnp.ndarray, v1: jnp.ndarray, rand: jnp.ndarray,
                  p: float, q: float, block_w: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """Persistent fused walk: steps 1..length-1 for all walkers, prev rows
    carried in VMEM. adj/wgt [n, D] (D a LANE multiple), deg [n], u0/v1 [W]
    (start vertex / step-0 result), rand [W, length-1] uniforms. Returns
    [W, length-1] sampled vertices (v_2..v_length)."""
    n, d = adj.shape
    wk, steps = rand.shape
    assert d % LANE == 0, d
    assert wk % block_w == 0, (wk, block_w)
    grid = (wk // block_w,)
    kernel = functools.partial(_walk_kernel, p_inv=1.0 / p, q_inv=1.0 / q,
                               length=steps + 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),       # graph: replicated
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_w, steps), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, steps), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wk, steps), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_w, d), jnp.int32)],
        interpret=interpret,
    )(adj, wgt, deg.reshape(n, 1), u0.reshape(wk, 1), v1.reshape(wk, 1),
      rand)
