"""Pure-jnp oracles for the Pallas kernels (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp



def node2vec_step_ref(cand_ids, cand_w, u, prev_ids, rand, p, q):
    """Reference for kernels.node2vec_step: the shared Sampler's exact draw
    (count of cumsum entries <= r*total over valid lanes) — the contract is
    written exactly once, in ``repro.engine.sampler.exact_slots``."""
    from repro.engine.sampler import exact_slots
    return exact_slots(cand_ids, cand_w, u, prev_ids, rand, p, q)


def flash_attention_ref(q, k, v, window: int = 0, causal: bool = True):
    """Reference for kernels.flash_attention: materialized-scores attention.
    q/k/v [BH, S, dh]."""
    s = q.shape[1]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    logits = jnp.where(ok[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def sgns_fused_ref(ci, po, no, valid):
    """Reference for kernels.sgns: loss sum + grads via jax autodiff."""

    def loss_fn(ci, po, no):
        pos = jnp.sum(ci * po, axis=-1)
        neg = jnp.einsum("bd,bkd->bk", ci, no)
        per = (jnp.logaddexp(0.0, -pos) +
               jnp.sum(jnp.logaddexp(0.0, neg), axis=-1))
        return jnp.sum(per * valid)

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(ci, po, no)
    return loss, grads[0], grads[1], grads[2]
