"""Jitted public wrappers around the Pallas kernels.

Handles the shape contract (pad walker count / widths to tile multiples),
chooses interpret mode off-TPU (this container is CPU-only; interpret=True
executes the kernel body faithfully for validation), and exposes drop-in
replacements for the jnp paths in the walk engine / SGNS trainer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import PAD_ID
from repro.kernels import node2vec_step as _step
from repro.kernels import sgns as _sgns


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, axis: int, mult: int, fill):
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def node2vec_step_op(cand_ids, cand_w, u, prev_ids, rand, p: float, q: float,
                     block_w: int = 256, interpret=None) -> jnp.ndarray:
    """Fused 2nd-order step; pads to the kernel tile contract and unpads."""
    if interpret is None:
        interpret = not _on_tpu()
    w = cand_ids.shape[0]
    bw = min(block_w, max(8, 1 << (w - 1).bit_length()))
    cand_ids = _pad_axis(_pad_axis(cand_ids, 1, _step.LANE, PAD_ID), 0, bw,
                         PAD_ID)
    cand_w = _pad_axis(_pad_axis(cand_w, 1, _step.LANE, 0.0), 0, bw, 0.0)
    prev_ids = _pad_axis(_pad_axis(prev_ids, 1, _step.LANE, PAD_ID), 0, bw,
                         PAD_ID)
    u = _pad_axis(u, 0, bw, 0)
    rand = _pad_axis(rand, 0, bw, 0.0)
    slots = _step.node2vec_step(cand_ids, cand_w, u, prev_ids, rand, p, q,
                                block_w=min(bw, cand_ids.shape[0]),
                                interpret=interpret)
    return slots[:w]


def node2vec_walk_op(adj, wgt, deg, u0, v1, rand, p: float, q: float,
                     block_w: int = 256, interpret=None) -> jnp.ndarray:
    """Persistent fused walk (prev rows carried in VMEM across supersteps);
    pads the graph width to the lane multiple and the walker count to the
    block multiple, then unpads. Returns [W, steps] sampled vertices."""
    if interpret is None:
        interpret = not _on_tpu()
    w = u0.shape[0]
    bw = min(block_w, max(8, 1 << (w - 1).bit_length()))
    adj = _pad_axis(adj, 1, _step.LANE, PAD_ID)
    wgt = _pad_axis(wgt, 1, _step.LANE, 0.0)
    u0 = _pad_axis(u0, 0, bw, 0)
    v1 = _pad_axis(v1, 0, bw, 0)
    rand = _pad_axis(rand, 0, bw, 0.0)
    out = _step.node2vec_walk(adj, wgt, deg, u0, v1, rand, p, q,
                              block_w=min(bw, u0.shape[0]),
                              interpret=interpret)
    return out[:w]


def flash_attention_op(q, k, v, window: int = 0, causal: bool = True,
                       block: int = 128, interpret=None):
    """Flash attention over model-layout tensors: q [B,S,H,dh],
    k/v [B,S,KV,dh] (GQA expanded here). Pads S to the block multiple and dh
    to the lane width."""
    from repro.kernels import flash_attention as _fa
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, dh = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    bq = min(block, max(8, 1 << (s - 1).bit_length()))

    def to_bh(x):
        x = jnp.swapaxes(x, 1, 2).reshape(b * h, s, dh)
        x = _pad_axis(x, 2, 128, 0.0)
        return _pad_axis(x, 1, bq, 0.0)

    qq, kk, vv = map(to_bh, (q, k, v))
    out = _fa.flash_attention(qq, kk, vv, block=min(bq, qq.shape[1]),
                              window=window, causal=causal,
                              interpret=interpret, sm_scale=dh ** -0.5)
    out = out[:, :s, :dh].reshape(b, h, s, dh)
    return jnp.swapaxes(out, 1, 2)


def sgns_fused_op(ci, po, no, valid, block_b: int = 512, interpret=None):
    """Fused SGNS loss+grads; returns (loss_sum, g_ci, g_po, g_no)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, d = ci.shape
    bb = min(block_b, max(8, 1 << (b - 1).bit_length()))
    ci_p = _pad_axis(_pad_axis(ci, 1, _sgns.LANE, 0.0), 0, bb, 0.0)
    po_p = _pad_axis(_pad_axis(po, 1, _sgns.LANE, 0.0), 0, bb, 0.0)
    no_p = _pad_axis(_pad_axis(no, 2, _sgns.LANE, 0.0), 0, bb, 0.0)
    valid_p = _pad_axis(valid, 0, bb, 0.0)
    loss, g_ci, g_po, g_no = _sgns.sgns_fused(
        ci_p, po_p, no_p, valid_p, block_b=min(bb, ci_p.shape[0]),
        interpret=interpret)
    return loss, g_ci[:b, :d], g_po[:b, :d], g_no[:b, :, :d]
