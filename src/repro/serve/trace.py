"""Synthetic serving traffic — Zipf node popularity, Poisson-ish arrivals.

Recommendation traffic is heavy-tailed in exactly the way the graphs are:
a few hub entities take most queries (the Tencent serving workload in
PAPERS.md). Under ``relabel=degree`` the graph's id order *is* degree order,
so drawing node ids from a Zipf over ``[0, n)`` makes query popularity track
vertex degree — the regime the FN-Cache-style admission policy is built for.

A trace is a list of :class:`TraceEvent` with relative arrival offsets; the
driver (``launch/serve_graph`` / ``benchmarks/bench_serve``) replays it
against a real or virtual clock.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arriving query: ``kind`` in {"embed", "rank"}, arrival offset in
    seconds from trace start, and a relative deadline budget."""
    kind: str
    node: int
    t_arrival: float
    deadline_s: float


def zipf_nodes(n: int, num: int, alpha: float = 1.1,
               seed: int = 0) -> np.ndarray:
    """``num`` node ids in ``[0, n)``, Zipf(alpha)-distributed by rank.
    Explicit inverse-CDF over the truncated support (numpy's ``zipf``
    resamples an unbounded tail, which is slow and bias-prone when ``n`` is
    small)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    pmf = ranks ** (-alpha)
    cdf = np.cumsum(pmf / pmf.sum())
    u = np.random.default_rng(seed).random(num)
    return np.searchsorted(cdf, u).astype(np.int64).clip(0, n - 1)


def synthetic_trace(n: int, num: int, alpha: float = 1.1,
                    rank_share: float = 0.5, qps: float = 10_000.0,
                    deadline_s: float = 0.05, seed: int = 0
                    ) -> List[TraceEvent]:
    """A Zipf query trace: ``num`` events over ``[0, num/qps)`` seconds,
    ``rank_share`` of them ``rank`` queries (the rest ``embed``), exponential
    inter-arrivals at mean rate ``qps``, one deadline budget for all."""
    rng = np.random.default_rng(seed + 1)
    nodes = zipf_nodes(n, num, alpha=alpha, seed=seed)
    gaps = rng.exponential(1.0 / qps, size=num)
    arrivals = np.cumsum(gaps) - gaps[0]
    kinds = np.where(rng.random(num) < rank_share, "rank", "embed")
    return [TraceEvent(kind=str(k), node=int(v), t_arrival=float(t),
                       deadline_s=deadline_s)
            for k, v, t in zip(kinds, nodes, arrivals)]
