"""repro.serve — online node-embedding serving over the resident graph +
SGNS tables (DESIGN.md §13).

    service = EmbeddingService(graph, emb, plan=WalkPlan(cap=32))
    rid = service.submit("rank", node, k=10, deadline_s=0.05)
    for resp in service.pump(): ...
    service.stats()        # ServeStats: p50/p99 latency, QPS, hit rate

Layers: ``DeadlineBatcher`` (deadline-aware coalescing into fixed-shape jit
buckets) -> ``ResultCache`` (LRU + FN-Cache hot-set admission) ->
``EmbeddingService`` (resident state + kernels) -> ``ServeStats``.
"""
from repro.serve.batcher import (DEFAULT_BUCKETS, DeadlineBatcher, Request,
                                 Response, VirtualClock, bucket_for)
from repro.serve.cache import (ResultCache, hot_set_admission,
                               prefix_admission)
from repro.serve.service import EmbeddingService
from repro.serve.stats import ServeStats, StatsRecorder
from repro.serve.trace import TraceEvent, synthetic_trace, zipf_nodes

__all__ = [
    "DEFAULT_BUCKETS", "DeadlineBatcher", "EmbeddingService", "Request",
    "Response", "ResultCache", "ServeStats", "StatsRecorder", "TraceEvent",
    "VirtualClock", "bucket_for", "hot_set_admission", "prefix_admission",
    "synthetic_trace", "zipf_nodes",
]
