"""ServeStats — the serving layer's structured diagnostics, mirroring
``repro.engine.plan.WalkStats`` (DESIGN.md §13).

The walk engine reports what one *run* did (supersteps, drops, collective
bytes); the serving layer reports what a *traffic window* did: request
latency quantiles, sustained QPS, cache hit rate, and how full the
fixed-shape jit batches actually were. ``StatsRecorder`` is the mutable
accumulator the service feeds per event; :meth:`StatsRecorder.snapshot`
freezes it into a :class:`ServeStats` record.

Latency is recorded against the service clock (injectable — the smoke
bench replays traces on a virtual clock so occupancy/hit-rate metrics are
deterministic; the launcher uses the real clock so p50/p99 measure actual
compute).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Frozen per-window serving diagnostics.

    ``requests``        — completed requests (answered, from cache or batch).
    ``expired``         — requests shed because their deadline passed before
                          a batch picked them up (starved queue / overload);
                          not counted in ``requests`` or the latency stats.
    ``batches``         — jit'd batches actually launched (cache hits and
                          expiries never reach a batch).
    ``p50_latency_us``  — median submit→response latency.
    ``p99_latency_us``  — tail latency (the serving SLO quantity).
    ``qps``             — completed requests / window wall time.
    ``cache_hit_rate``  — hits / (hits + misses) over result-cache lookups.
    ``batch_occupancy`` — mean(real items / bucket slots) over launched
                          batches; low occupancy means the coalescer is
                          padding, high means buckets are sized right.
    """
    requests: int = 0
    expired: int = 0
    batches: int = 0
    p50_latency_us: float = 0.0
    p99_latency_us: float = 0.0
    qps: float = 0.0
    cache_hit_rate: float = 0.0
    batch_occupancy: float = 0.0


class StatsRecorder:
    """Mutable accumulator behind :class:`ServeStats`."""

    def __init__(self) -> None:
        self._latencies_us: list[float] = []
        self.hits = 0
        self.misses = 0
        self.expired = 0
        self._occupancies: list[float] = []
        self._t_first: float | None = None
        self._t_last: float | None = None

    # ------------------------------------------------------------ events --
    def request_submitted(self, now: float) -> None:
        if self._t_first is None or now < self._t_first:
            self._t_first = now

    def request_completed(self, t_submit: float, now: float) -> None:
        self._latencies_us.append((now - t_submit) * 1e6)
        if self._t_last is None or now > self._t_last:
            self._t_last = now

    def request_expired(self) -> None:
        self.expired += 1

    def cache_lookup(self, hit: bool) -> None:
        if hit:
            self.hits += 1
        else:
            self.misses += 1

    def batch_launched(self, real_items: int, bucket: int) -> None:
        self._occupancies.append(real_items / max(bucket, 1))

    # ---------------------------------------------------------- snapshot --
    def snapshot(self) -> ServeStats:
        lat = np.asarray(self._latencies_us, np.float64)
        window = 0.0
        if self._t_first is not None and self._t_last is not None:
            window = max(self._t_last - self._t_first, 0.0)
        looks = self.hits + self.misses
        return ServeStats(
            requests=len(lat),
            expired=self.expired,
            batches=len(self._occupancies),
            p50_latency_us=float(np.percentile(lat, 50)) if lat.size else 0.0,
            p99_latency_us=float(np.percentile(lat, 99)) if lat.size else 0.0,
            qps=len(lat) / window if window > 0 else 0.0,
            cache_hit_rate=self.hits / looks if looks else 0.0,
            batch_occupancy=float(np.mean(self._occupancies))
            if self._occupancies else 0.0,
        )
