"""EmbeddingService — online node-embedding queries over a resident graph +
trained SGNS table (DESIGN.md §13).

The walk engine turns a graph into embeddings; this is the layer that turns
those embeddings into answers under traffic — the "millions of users"
serving story (ROADMAP; Tencent's recommendation workload in PAPERS.md).
One service instance holds, resident on device:

* the FN-Cache graph layout (``PaddedGraph``: capped cold rows + replicated
  hot rows) — the same arrays the walk engine samples from;
* the L2-normalized SGNS ``emb`` table ``[V, D]``.

Two query kinds:

* ``embed(nodes, window=0)`` — gather rows; with ``window > 0`` the result
  is the normalized mean of the node's row and a ``window``-step node2vec
  walk context from it (the query-time analogue of the training-time
  context window). Walks run through the resident ``WalkEngine`` with
  walker id == node id, so a node's walk context — and therefore its
  embedding — is a pure function of (node, service seed), independent of
  batch composition. That is what makes coalesced serving bit-identical to
  per-request serving (tested).
* ``rank_neighbors(node, k)`` — top-k dot-product ranking of a candidate
  set: the node's graph neighbors (default) or the full vocabulary
  (``scope="all"``).

The request path is ``submit() -> pump()`` through a
:class:`~repro.serve.batcher.DeadlineBatcher` (fixed-shape jit buckets, no
per-request recompiles) with a :class:`~repro.serve.cache.ResultCache` in
front (LRU, hot-set admission). ``stats()`` snapshots the
:class:`~repro.serve.stats.ServeStats` window.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PaddedGraph
from repro.engine import WalkEngine, WalkPlan
from repro.serve.batcher import (DEFAULT_BUCKETS, DeadlineBatcher, Response,
                                 bucket_for)
from repro.serve.cache import (Admission, ResultCache, hot_set_admission,
                               prefix_admission)
from repro.serve.stats import ServeStats, StatsRecorder


# ------------------------------------------------------------------ kernels
# Module-level jit'd kernels: compilation is cached per (shape, static
# args), and the batcher only ever presents bucket shapes, so the compile
# set is bounded by buckets x query groups (asserted in tests).

@jax.jit
def _gather_kernel(emb: jnp.ndarray, nodes: jnp.ndarray) -> jnp.ndarray:
    return emb[nodes]


@jax.jit
def _walk_avg_kernel(emb: jnp.ndarray, nodes: jnp.ndarray,
                     walks: jnp.ndarray) -> jnp.ndarray:
    ctx = emb[walks]                                  # [B, window, D]
    mean = (emb[nodes] + jnp.sum(ctx, axis=1)) / (walks.shape[1] + 1)
    return mean / (jnp.linalg.norm(mean, axis=-1, keepdims=True) + 1e-8)


@functools.partial(jax.jit, static_argnames=("k",))
def _rank_neighbors_kernel(emb: jnp.ndarray, nodes: jnp.ndarray,
                           cand: jnp.ndarray, k: int):
    q = emb[nodes]                                    # [B, D]
    valid = cand >= 0
    ce = emb[jnp.clip(cand, 0, emb.shape[0] - 1)]     # [B, W, D]
    scores = jnp.where(valid, jnp.einsum("bd,bwd->bw", q, ce), -jnp.inf)
    if k > scores.shape[1]:                           # static widths
        fill = ((scores.shape[0], k - scores.shape[1]))
        scores = jnp.concatenate(
            [scores, jnp.full(fill, -jnp.inf, scores.dtype)], axis=1)
        cand = jnp.concatenate(
            [cand, jnp.full(fill, -1, cand.dtype)], axis=1)
    top_s, top_i = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(cand, top_i, axis=1)
    return jnp.where(jnp.isfinite(top_s), top_ids, -1), top_s


@functools.partial(jax.jit, static_argnames=("k",))
def _rank_all_kernel(emb: jnp.ndarray, nodes: jnp.ndarray, k: int):
    scores = emb[nodes] @ emb.T                       # [B, V]
    scores = scores.at[jnp.arange(nodes.shape[0]), nodes].set(-jnp.inf)
    return jax.lax.top_k(scores, k)


class EmbeddingService:
    """Resident-state serving over one graph + one embedding table.

    ``graph`` is anything ``repro.data.open_graph`` accepts (spec string,
    ``CSRGraph``, ``Dataset``, ``GraphStore``); the service holds the store
    and supports zero-downtime edge deltas via :meth:`refresh`.
    """

    def __init__(self, graph, emb, *,
                 plan: Optional[WalkPlan] = None,
                 cache_size: int = 1024,
                 admission: Union[str, Admission, None] = "hot",
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 linger_s: float = 0.0, margin_s: float = 0.0,
                 walk_seed: int = 0, clock=time.monotonic) -> None:
        from repro.data import open_graph
        self.store = open_graph(graph)   # spec | CSRGraph | Dataset | store
        graph = self.store.graph
        self.graph = graph
        self.plan = plan or WalkPlan(backend="reference")
        if self.plan.backend == "sharded" and jax.device_count() > 1:
            raise ValueError(
                "EmbeddingService serves from one replica; per-query walk "
                "windows need walker-aligned starts, which the multi-shard "
                "backend cannot give arbitrary query nodes. Hold one "
                "PaddedGraph per serving replica (backend='reference' or "
                "'fused') and shard *traffic*, not the graph.")
        if isinstance(emb, dict):            # raw SGNS params pytree
            from repro.core.skipgram import serving_table
            emb = serving_table(emb)
        emb = np.asarray(jax.device_get(emb), np.float32)
        if emb.ndim != 2 or emb.shape[0] < graph.n:
            raise ValueError(
                f"emb must be [V >= n, D], got {emb.shape} for n={graph.n}")
        self.emb = jnp.asarray(emb)
        self.dim = int(emb.shape[1])
        # resident FN-Cache layout, shared by every per-window walk engine
        self._pg = PaddedGraph.build(graph, cap=self.plan.cap,
                                     hot_cap=self.plan.hot_cap)
        self._engines: Dict[int, WalkEngine] = {}
        self._cand_width = max(graph.max_degree, 1)
        if admission == "hot":
            # FN-Cache hot set when the layout has one; else the same idea
            # via degree rank (top cache_size vertices by degree)
            if self.plan.cap is not None:
                admission = hot_set_admission(graph.deg, self.plan.cap)
            else:
                order = np.argsort(-graph.deg.astype(np.int64),
                                   kind="stable")
                hot = np.zeros(graph.n, bool)
                hot[order[:cache_size]] = True
                admission = lambda v: bool(0 <= v < graph.n and hot[v])
        elif isinstance(admission, str) and admission.startswith("prefix:"):
            admission = prefix_admission(int(admission.split(":", 1)[1]))
        self.cache = ResultCache(cache_size, admit=admission)
        self.batcher = DeadlineBatcher(tuple(buckets), linger_s=linger_s,
                                       margin_s=margin_s)
        self.recorder = StatsRecorder()
        self.walk_seed = walk_seed
        self.clock = clock
        self._ready: List[Response] = []
        self.compiled_shapes: set = set()

    # ------------------------------------------------------------- build --
    @classmethod
    def from_node2vec(cls, graph, cfg, mesh=None, **kw) -> "EmbeddingService":
        """Run the full pipeline (walks -> SGNS) and serve the result."""
        from repro.core.node2vec import node2vec
        from repro.data import open_graph
        store = open_graph(graph)
        emb = node2vec(store.graph, cfg, mesh=mesh)
        plan = kw.pop("plan", None) or dataclasses.replace(
            cfg.plan(mesh), backend="reference")
        return cls(store, emb, plan=plan, **kw)

    # ------------------------------------------------------------ refresh --
    def refresh(self, deltas) -> dict:
        """Apply edge deltas to the resident graph without taking the
        service down: the store patches the host CSR, only the affected
        rows of the resident ``PaddedGraph`` are respliced
        (``repro.engine.update.patch_padded``), the per-window walk engines
        are rebound to the new layout, and cached results keyed on affected
        nodes are dropped. Unaffected nodes keep their device rows *and*
        their cache entries.

        Frozen across refreshes (rebuild the service to re-derive): the
        admission predicate's degree snapshot and the embedding table —
        deltas move the graph, not the trained SGNS table, so walk-window
        embeddings of affected nodes change only through their walk
        context. Returns a report dict (patch + device accounting).
        """
        from repro.engine.update import patch_padded
        patch = self.store.apply(deltas)
        self.graph = self.store.graph
        aff = patch.affected
        self._pg, relayout, hot_rows = patch_padded(
            self._pg, self.graph, aff, self.plan.cap, self.plan.hot_cap)
        # per-window engines hold the old PaddedGraph; rebind lazily
        self._engines.clear()
        # rank candidate width only ever grows: compiled rank-kernel shapes
        # stay valid and new, longer neighbor rows still fit
        self._cand_width = max(self._cand_width, self.graph.max_degree, 1)
        dropped = self.cache.invalidate_nodes(aff)
        return {
            "version": self.store.version,
            "relayout": relayout,
            "num_affected": int(patch.num_affected),
            "delta_edges": int(patch.delta_edges),
            "invalidated_fraction":
                1.0 if relayout else float(patch.shard_fraction),
            "hot_rows_updated": int(hot_rows),
            "cache_entries_dropped": int(dropped),
        }

    # ------------------------------------------------------------ warming --
    def warm_from_walks(self, walks, *, window: int = 0,
                        top: Optional[int] = None) -> int:
        """Pre-populate the ResultCache from walk-visit counts (ROADMAP
        §serve remaining depth).

        The last walk round of training is a free popularity oracle: a
        vertex's visit count is proportional to its stationary walk
        probability, which is exactly the degree-skew the admission policy
        and Zipf traffic follow. Rank vertices by visits in ``walks``
        (any ``[W, L]`` int array), keep the admitted ones, and compute
        their ``("embed", node, window)`` entries through the normal
        batched path — so a warmed entry is bit-identical to the one a cold
        query would have produced (``embed`` is batch-composition
        independent). ``top`` caps how many to warm (default: cache
        capacity). Returns the number of entries cached.
        """
        counts = np.bincount(
            np.asarray(walks, np.int64).ravel(), minlength=self.graph.n)
        order = np.argsort(-counts, kind="stable")
        order = order[counts[order] > 0]
        if self.cache.admit is not None:
            order = np.asarray([v for v in order if self.cache.admit(int(v))],
                               np.int64)
        budget = self.cache.capacity if top is None else min(
            top, self.cache.capacity)
        nodes = order[:budget].astype(np.int32)
        warmed = 0
        step = max(self.batcher.buckets)
        for i in range(0, len(nodes), step):
            chunk = nodes[i:i + step]
            rows = self.embed(chunk, window=window)
            for v, val in zip(chunk, rows):
                warmed += self.cache.put(("embed", int(v), window), val,
                                         node=int(v))
        return warmed

    def _engine_for(self, window: int) -> WalkEngine:
        eng = self._engines.get(window)
        if eng is None:
            plan = dataclasses.replace(self.plan, length=window)
            eng = WalkEngine.build(self._pg, plan)
            self._engines[window] = eng
        return eng

    # ----------------------------------------------------- direct queries --
    def _pad(self, nodes: np.ndarray) -> Tuple[np.ndarray, int]:
        b = bucket_for(len(nodes), self.batcher.buckets)
        padded = np.zeros(b, np.int32)
        padded[:len(nodes)] = nodes
        return padded, b

    def embed(self, nodes, window: int = 0) -> np.ndarray:
        """[B, D] embeddings for ``nodes`` — direct (cache/queue-bypassing)
        batched path; the queued path computes through this same code, so
        the two are bit-identical by construction."""
        nodes = np.atleast_1d(np.asarray(nodes, np.int32))
        padded, b = self._pad(nodes)
        jnodes = jnp.asarray(padded)
        if window <= 0:
            self.compiled_shapes.add(("gather", b))
            out = _gather_kernel(self.emb, jnodes)
        else:
            res = self._engine_for(window).run(
                starts=padded, seed=self.walk_seed, walker_ids=padded)
            self.compiled_shapes.add(("walk_avg", b, window))
            out = _walk_avg_kernel(self.emb, jnodes,
                                   jnp.asarray(res.walks, jnp.int32))
        return np.asarray(out)[:len(nodes)]

    def _neighbor_rows(self, nodes: np.ndarray) -> np.ndarray:
        rows = np.full((len(nodes), self._cand_width), -1, np.int32)
        for i, v in enumerate(nodes):
            nb = self.graph.neighbors(int(v))
            rows[i, :len(nb)] = nb
        return rows

    def rank_neighbors(self, nodes, k: int,
                       scope: str = "neighbors"
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-``k`` candidates by dot product for each query node:
        ``(ids [B, k], scores [B, k])``; ids are -1 past the candidate count.
        ``scope="neighbors"`` ranks the node's graph neighborhood (the
        recommender re-ranking shape); ``"all"`` scans the full table."""
        if scope not in ("neighbors", "all"):
            raise ValueError(f"scope must be neighbors|all, got {scope!r}")
        nodes = np.atleast_1d(np.asarray(nodes, np.int32))
        padded, b = self._pad(nodes)
        jnodes = jnp.asarray(padded)
        if scope == "all":
            self.compiled_shapes.add(("rank_all", b, k))
            top_s, top_i = _rank_all_kernel(self.emb, jnodes, k)
            ids, scores = np.asarray(top_i), np.asarray(top_s)
        else:
            cand = self._neighbor_rows(padded)
            self.compiled_shapes.add(("rank", b, k))
            top_i, top_s = _rank_neighbors_kernel(
                self.emb, jnodes, jnp.asarray(cand), k)
            ids, scores = np.asarray(top_i), np.asarray(top_s)
        return ids[:len(nodes)], scores[:len(nodes)]

    # ----------------------------------------------------- queued serving --
    def submit(self, kind: str, node: int, *, window: int = 0,
               k: int = 10, scope: str = "neighbors",
               deadline_s: float = math.inf,
               now: Optional[float] = None) -> int:
        """Enqueue one query; returns its request id. Cache hits are
        answered immediately (delivered by the next ``pump``)."""
        explicit = now is not None
        now = self.clock() if not explicit else now
        self.recorder.request_submitted(now)
        if kind == "embed":
            key = ("embed", int(node), window)
            group = ("embed", window)
        elif kind == "rank":
            key = ("rank", int(node), k, scope)
            group = ("rank", k, scope)
        else:
            raise ValueError(f"kind must be embed|rank, got {kind!r}")
        cached = self.cache.get(key)
        self.recorder.cache_lookup(cached is not None)
        if cached is not None:
            rid = self.batcher.next_rid()        # answered without queueing
            done = now if explicit else self.clock()
            self._ready.append(Response(rid=rid, value=cached,
                                        t_submit=now, t_done=done))
            self.recorder.request_completed(now, done)
            return rid
        req = self.batcher.submit(group, node,
                                  deadline=now + deadline_s, now=now)
        return req.rid

    def _compute_group(self, group: Tuple, nodes: np.ndarray) -> list:
        """Batched compute for unique ``nodes`` of one group; returns one
        value per node (row / (ids, scores) tuple)."""
        if group[0] == "embed":
            out = self.embed(nodes, window=group[1])
            return [out[i] for i in range(len(nodes))]
        _, k, scope = group
        ids, scores = self.rank_neighbors(nodes, k, scope=scope)
        return [(ids[i], scores[i]) for i in range(len(nodes))]

    def pump(self, now: Optional[float] = None,
             drain: bool = False) -> List[Response]:
        """Flush due batches and return completed/expired responses (plus
        any cache-hit responses since the last pump). When the caller
        supplies ``now`` it owns the time base (trace replay on a virtual
        clock); otherwise the service clock stamps completions after each
        batch, so latencies include compute."""
        explicit = now is not None
        now = self.clock() if not explicit else now
        responses, self._ready = self._ready, []
        for group, live, dead in self.batcher.due(now, drain=drain):
            for r in dead:
                self.recorder.request_expired()
                responses.append(Response(rid=r.rid, value=None, expired=True,
                                          t_submit=r.t_submit, t_done=now))
            if not live:
                continue
            uniq, inv = np.unique(
                np.asarray([r.node for r in live], np.int64),
                return_inverse=True)
            bucket = bucket_for(len(uniq), self.batcher.buckets)
            self.recorder.batch_launched(len(uniq), bucket)
            values = self._compute_group(group, uniq.astype(np.int32))
            done = now if explicit else self.clock()
            for r, j in zip(live, inv):
                value = values[int(j)]
                if group[0] == "embed":
                    self.cache.put(("embed", r.node, group[1]), value,
                                   node=r.node)
                else:
                    self.cache.put(("rank", r.node, group[1], group[2]),
                                   value, node=r.node)
                responses.append(Response(rid=r.rid, value=value,
                                          t_submit=r.t_submit, t_done=done))
                self.recorder.request_completed(r.t_submit, done)
        return responses

    def drain(self, now: Optional[float] = None) -> List[Response]:
        return self.pump(now=now, drain=True)

    def stats(self) -> ServeStats:
        return self.recorder.snapshot()
