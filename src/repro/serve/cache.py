"""Result cache for hot nodes — LRU eviction, FN-Cache-style admission
(DESIGN.md §13).

The walk layer's FN-Cache observation (paper §3.4) carries over to serving
unchanged: Zipf traffic concentrates on exactly the vertices whose degree is
highest, so a small replicated structure keyed on the hot set absorbs most
of the load. Here the structure is a result cache, and the *admission*
policy — not just eviction — is what keeps it hot: a one-off cold query must
not evict a hub's entry, so cold nodes bypass the cache entirely.

Two admission predicates reuse the existing hot-set machinery:

* :func:`hot_set_admission` — membership in the FN-Cache hot set
  (``degree > cap``), taken from the resident graph's degrees; identical to
  the set ``PaddedGraph.build`` replicates.
* :func:`prefix_admission` — ``id < K``: under the ingest registry's
  ``relabel=degree`` (PR 4) the hot set is the contiguous id prefix, so
  admission is a single compare, no lookup table.

Keys are opaque tuples (the service uses ``(kind, node, ...)``); admission
sees only the node id.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional

import numpy as np

Admission = Callable[[int], bool]


def prefix_admission(k: int) -> Admission:
    """Admit node ids in the contiguous hot prefix ``[0, k)`` — the
    ``relabel=degree`` layout where degree rank == vertex id."""
    return lambda node: 0 <= node < k


def hot_set_admission(deg: np.ndarray, cap: int) -> Admission:
    """Admit the FN-Cache hot set: nodes with ``degree > cap`` (the same
    vertices whose rows ``PaddedGraph.build``/``ShardedGraph`` replicate)."""
    hot = np.asarray(deg) > cap

    def admit(node: int) -> bool:
        return bool(0 <= node < hot.shape[0] and hot[node])

    return admit


class ResultCache:
    """LRU cache over query results with an admission predicate.

    ``get`` refreshes recency on hit; ``put`` inserts only if the admission
    predicate accepts the node (rejections are not errors — the caller just
    serves the computed value uncached). Eviction is strict LRU among the
    admitted entries. ``hits``/``misses`` counters feed ``ServeStats``.
    """

    def __init__(self, capacity: int,
                 admit: Optional[Admission] = None) -> None:
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.admit = admit
        self.hits = 0
        self.misses = 0
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        """Keys in eviction order: least-recently-used first."""
        return list(self._entries.keys())

    def get(self, key: Hashable):
        """Value for ``key`` (refreshing recency) or None; counts hit/miss."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        return None

    def invalidate_nodes(self, nodes) -> int:
        """Drop every entry keyed on one of ``nodes`` (the second element
        of a tuple key, the service convention) — the serving side of a
        graph delta: results computed from a patched vertex's old row must
        not outlive it. Returns the number of entries dropped."""
        ns = {int(v) for v in np.asarray(nodes, np.int64).ravel()}
        if not ns:
            return 0
        drop = [k for k in self._entries
                if isinstance(k, tuple) and len(k) > 1 and int(k[1]) in ns]
        for k in drop:
            del self._entries[k]
        return len(drop)

    def put(self, key: Hashable, value: Any, node: Optional[int] = None
            ) -> bool:
        """Insert ``value`` if admission accepts ``node`` (default: the
        second element of a tuple key, the service's key convention).
        Returns True iff the entry was admitted."""
        if node is None and isinstance(key, tuple) and len(key) > 1:
            node = key[1]
        if self.admit is not None and node is not None \
                and not self.admit(int(node)):
            return False
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        return True
