"""Deadline-aware request coalescing into fixed-shape batches (DESIGN §13).

jax recompiles per input shape, so a serving loop that launched one gather
per request — or batches of whatever size happened to be queued — would
either serialize on tiny kernels or recompile continuously. The coalescer
holds the middle ground:

* Requests queue per *group* (query kind + its static params, e.g.
  ``("embed", window)`` — groups share a jit'd kernel).
* A group flushes when it can fill the largest bucket, when its oldest
  request has lingered ``linger_s`` (latency floor), or when any member's
  deadline is within ``margin_s`` of now (deadline-aware: a request about to
  expire pulls its batchmates along instead of waiting for occupancy).
* Flushed batches are padded **up** to the smallest bucket that fits
  (``buckets`` is the full set of shapes the service ever compiles — no
  per-request recompiles by construction).
* Requests whose deadline already passed at flush time are *shed*: they get
  an ``expired`` response without touching the accelerator (overload sheds
  work instead of queueing it — the starved-queue tests pin this down).

The batcher is single-threaded and pull-based: callers ``submit`` then
``due(now)``/``drain(now)``. Time is an explicit argument everywhere, so
trace replay on a virtual clock is deterministic regardless of machine load
— same request multiset in a different arrival order gives bit-identical
responses (tested).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, Hashable, List, Optional, Tuple

DEFAULT_BUCKETS = (8, 32, 128)


@dataclasses.dataclass
class Request:
    """One enqueued query. ``group`` picks the kernel; ``node``/``extra``
    are its payload; ``deadline`` is absolute service-clock time (+inf =
    never expires)."""
    rid: int
    group: Tuple
    node: int
    extra: Tuple = ()
    deadline: float = math.inf
    t_submit: float = 0.0


@dataclasses.dataclass
class Response:
    """Answer to one request. ``expired`` responses carry ``value=None``."""
    rid: int
    value: Any
    expired: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0


def bucket_for(n: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket holding ``n`` items (callers never exceed the largest
    bucket per flush)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class DeadlineBatcher:
    """Per-group request queues + the flush policy described above."""

    def __init__(self, buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                 linger_s: float = 0.0, margin_s: float = 0.0) -> None:
        if not buckets or any(b < 1 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_bucket = self.buckets[-1]
        self.linger_s = linger_s
        self.margin_s = margin_s
        self._queues: Dict[Hashable, List[Request]] = {}
        self._rid = itertools.count()

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def next_rid(self) -> int:
        """Allocate a request id without queueing (cache-hit fast path)."""
        return next(self._rid)

    def submit(self, group: Tuple, node: int, extra: Tuple = (),
               deadline: float = math.inf, now: float = 0.0) -> Request:
        req = Request(rid=next(self._rid), group=group, node=int(node),
                      extra=tuple(extra), deadline=deadline, t_submit=now)
        self._queues.setdefault(group, []).append(req)
        return req

    # ------------------------------------------------------------ flushes --
    def _flush_group(self, q: List[Request], force: bool,
                     now: float) -> List[List[Request]]:
        out = []
        while len(q) >= self.max_bucket:
            out.append(q[:self.max_bucket])
            del q[:self.max_bucket]
        if q and (force
                  or now - q[0].t_submit >= self.linger_s
                  or min(r.deadline for r in q) - now <= self.margin_s):
            out.append(q[:])
            q.clear()
        return out

    def due(self, now: float, drain: bool = False
            ) -> List[Tuple[Hashable, List[Request], List[Request]]]:
        """Batches ready to launch at ``now``: a list of
        ``(group, live_requests, expired_requests)``. ``drain=True`` flushes
        everything regardless of linger/occupancy (end of trace / shutdown).
        Within a batch, requests keep submission order — with the
        per-request RNG keyed on node id (never batch position), response
        values are a pure function of the request, so arrival order cannot
        change them."""
        ready = []
        for group in sorted(self._queues, key=repr):
            for batch in self._flush_group(self._queues[group], drain, now):
                live = [r for r in batch if r.deadline >= now]
                dead = [r for r in batch if r.deadline < now]
                ready.append((group, live, dead))
        return ready

    def drain(self, now: float):
        return self.due(now, drain=True)


class VirtualClock:
    """Deterministic clock for trace replay: ``now`` advances only when the
    driver says so. Also callable, matching ``time.monotonic``'s shape."""

    def __init__(self, start: float = 0.0) -> None:
        self.t = start

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t
