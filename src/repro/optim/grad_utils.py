"""Gradient utilities for distributed training.

* global-norm clipping
* gradient accumulation (microbatching) wrapper
* int8 error-feedback gradient compression — the distributed-optimization
  trick for shrinking data-parallel all-reduce bytes 4x: gradients are
  quantized to int8 with a per-tensor scale before the cross-replica
  reduction; the quantization residual is fed back into the next step's
  gradient (error feedback keeps SGD convergence guarantees).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: g * scale, grads), norm


def accumulate_gradients(loss_fn, params, batch, num_microbatches: int):
    """Split ``batch`` (leading axis) into microbatches; scan-accumulate
    gradients. Cuts activation memory by ``num_microbatches``."""

    def micro(b):
        return jax.value_and_grad(loss_fn)(params, b)

    if num_microbatches <= 1:
        return micro(batch)

    micro_batches = jax.tree.map(
        lambda x: x.reshape((num_microbatches, x.shape[0] // num_microbatches)
                            + x.shape[1:]), batch)

    def body(carry, mb):
        acc_loss, acc_grads = carry
        loss, grads = micro(mb)
        return (acc_loss + loss,
                jax.tree.map(jnp.add, acc_grads, grads)), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                    micro_batches)
    inv = 1.0 / num_microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


# ---------------- int8 error-feedback compression ----------------

def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compressed_psum(grads, residuals, axis_name: Optional[str] = None):
    """Quantize (grad + residual) to int8, all-reduce the int8 payload (4x
    fewer collective bytes), dequantize, and return the new residuals.

    When ``axis_name`` is None (single-replica tests) the psum is skipped but
    the quantization round-trip (and its error feedback) still happens, so the
    numerics are identical to the distributed path with one replica.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        if axis_name is not None:
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            scale = jax.lax.pmax(scale, axis_name)
            deq = qsum.astype(jnp.float32) * scale
            nrep = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            deq = deq / nrep
        else:
            deq = dequantize_int8(q, scale)
        new_r = g32 - dequantize_int8(q, scale)
        return deq.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residuals)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_res
