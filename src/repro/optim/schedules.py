"""Learning-rate schedules (pure functions of the step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def linear_warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                         floor: float = 0.0):
    """MaxText-style warmup -> cosine decay to ``floor``."""

    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * (c + 1) / max(warmup_steps, 1)
        progress = jnp.clip((c - warmup_steps) /
                            max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.where(c < warmup_steps, warm, cos)

    return fn


def inverse_sqrt(peak: float, warmup_steps: int):
    def fn(count):
        c = jnp.maximum(count.astype(jnp.float32), 1.0)
        return peak * jnp.minimum(c / max(warmup_steps, 1),
                                  jnp.sqrt(warmup_steps / c))

    return fn
