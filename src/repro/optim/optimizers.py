"""Pure-JAX optimizers (no optax in this environment — built as a substrate).

API mirrors the (init, update) transformation style:

    opt = adamw(lr=3e-4, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All states are pytrees, shardable by pjit with the same specs as params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


@dataclasses.dataclass(frozen=True, eq=False)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)
    # Hashable config identity: two factory calls with the same scalar
    # hyperparameters build functionally identical closures, so they should
    # hit the same jit cache entry when passed as a static argument (a fresh
    # ``adam(lr)`` per run must not recompile every donated-buffer program).
    # ``None`` (callable schedule / custom mask) falls back to object identity.
    key: Optional[tuple] = None

    def __eq__(self, other):
        if (self.key is not None and isinstance(other, Optimizer)
                and other.key is not None):
            return self.key == other.key
        return self is other

    def __hash__(self):
        return hash(self.key) if self.key is not None else id(self)


def _lr_at(lr: ScalarOrSchedule, count: jnp.ndarray) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


class SgdState(NamedTuple):
    count: jnp.ndarray
    momentum: Any


def sgd(lr: ScalarOrSchedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return SgdState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state, params=None):
        step_lr = _lr_at(lr, state.count)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g,
                               state.momentum, grads)
            updates = jax.tree.map(lambda m: -step_lr * m, mom)
        else:
            mom = None
            updates = jax.tree.map(lambda g: -step_lr * g, grads)
        return updates, SgdState(state.count + 1, mom)

    key = ("sgd", lr, momentum) if not callable(lr) else None
    return Optimizer(init, update, key)


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adam(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         mask: Optional[Callable[[Any], Any]] = None) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when ``weight_decay > 0``).

    ``mask(params)`` -> pytree of bools selecting which leaves get decay
    (default: everything with ndim >= 2, the usual no-decay-on-bias/norm rule).
    """

    def init(params):
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(jnp.zeros_like, params),
                         jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        count = state.count + 1
        step_lr = _lr_at(lr, state.count)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * (g * g),
                          state.nu, grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def upd(m, v):
            return -step_lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)

        updates = jax.tree.map(upd, mu, nu)
        if weight_decay and params is not None:
            decay_mask = (mask(params) if mask is not None else
                          jax.tree.map(lambda p: p.ndim >= 2, params))
            updates = jax.tree.map(
                lambda u, p, m: u - step_lr * weight_decay * p * m,
                updates, params, decay_mask)
        return updates, AdamState(count, mu, nu)

    key = ("adam", lr, b1, b2, eps, weight_decay) \
        if not callable(lr) and mask is None else None
    return Optimizer(init, update, key)


def adamw(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    return adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def adam_rows(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8) -> Optimizer:
    """Row-sparse ("lazy") Adam for embedding tables.

    Dense :func:`adam` decays the moments of *every* row every step — O(V·D)
    table work per batch, the dominant cost once V outgrows the batch. Here
    moments live per table row and only the rows gathered for the current
    batch move; untouched rows keep their moments frozen (the standard
    lazy-Adam embedding semantics, e.g. TF's LazyAdam). This is what makes
    the sharded trainer's per-step cost O(touched-rows·D) instead of O(V·D).

    ``init(params)`` matches :func:`adam` (an :class:`AdamState` whose
    ``mu``/``nu`` mirror the tables, shardable with the same specs).
    ``update(g_rows, (mu_rows, nu_rows), count)`` operates on *gathered
    rows*: ``count`` is the already-incremented step, and it returns
    ``(row_updates, new_mu_rows, new_nu_rows)`` for the caller to scatter
    back — the caller owns row locality (which rows, which shard).
    """

    def init(params):
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(jnp.zeros_like, params),
                         jax.tree.map(jnp.zeros_like, params))

    def update(g_rows, rows_state, count):
        mu_rows, nu_rows = rows_state
        new_mu = b1 * mu_rows + (1 - b1) * g_rows
        new_nu = b2 * nu_rows + (1 - b2) * (g_rows * g_rows)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        step_lr = _lr_at(lr, count - 1)
        upd = -step_lr * (new_mu / bc1) / (jnp.sqrt(new_nu / bc2) + eps)
        return upd, new_mu, new_nu

    key = ("adam_rows", lr, b1, b2, eps) if not callable(lr) else None
    return Optimizer(init, update, key)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
