"""Atomic, async, resumable checkpointing (no orbax in this environment).

Layout: ``<dir>/step_<n>/arrays.npz`` + ``<dir>/step_<n>/meta.json``,
committed by atomically renaming a ``.tmp`` staging directory, then updating
``<dir>/LATEST``. A half-written checkpoint can therefore never be picked up
on restart — the fault-tolerance contract for node failures.

* ``save(..., blocking=False)`` hands the host copy to a writer thread so
  checkpointing overlaps training (device->host transfer is the only
  synchronous part).
* Pytrees are flattened to ``/``-joined key paths; restore rebuilds the tree
  and optionally ``device_put``s leaves with target shardings (which may
  belong to a *different* mesh shape — this is the elastic-rescale path used
  by ``runtime/fault_tolerance.py``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ----------------

    def save(self, step: int, tree: Any, meta: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()  # at most one in-flight async save
        flat = _flatten(jax.device_get(tree))
        meta = dict(meta or {})
        meta["step"] = int(step)
        meta["keys"] = sorted(flat.keys())
        meta["time"] = time.time()

        def write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            latest_tmp = os.path.join(self.directory, "LATEST.tmp")
            with open(latest_tmp, "w") as f:
                f.write(os.path.basename(final))
            os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------- restore ----------------

    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.directory, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.directory, name)):
            return None
        return int(name.split("_")[-1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``. ``shardings`` (same
        structure, of NamedSharding) re-places leaves — works across mesh
        shapes for elastic restarts."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        flat_template = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat_template[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = arrays[key]
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(flat_template[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta
