"""Fault tolerance + elastic scaling for the walk and train loops.

The paper's FN-Multi (simulate the n walks in k independent rounds, §3.4) is
the natural fault boundary: rounds are independent, so

* each completed round is checkpointed (atomic; see checkpoint/checkpointer);
* a crashed/preempted run resumes from the first incomplete round;
* because walker state is keyed by *vertex id* (never device id) and the RNG
  is ``fold_in(seed, walker, step)``, a restart may use a **different device
  count** — the graph and walkers are simply re-partitioned (elastic
  scaling). Resumed rounds are bit-identical to uninterrupted ones (tested).

For the LM train loop the equivalent contract is (params, opt_state, step)
checkpoints with shardings re-derived from whatever mesh the restart has
(checkpointer.restore accepts new shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.graph import CSRGraph
from repro.core.node2vec import Node2VecConfig
from repro.data.deltas import DeltaBatch
from repro.engine import WalkEngine, WalkStats, round_seed


class WalkRoundRunner:
    """Run FN-Multi walk rounds with checkpoint/resume.

    Each round r simulates one walk per vertex with seed fold(seed, r). The
    checkpoint stores the completed rounds' walks; ``rounds()`` yields each
    round's walks as it completes (consumed by the SGNS training pipeline,
    overlapping walk generation with optimization). Walks run through the
    unified ``WalkEngine`` — the engine (and its compiled walk fn) is built
    once per runner, so rounds never re-trace.

    Per-round :class:`WalkStats` are kept in ``round_stats`` and the
    *cumulative* drop/overlap accounting rides in the checkpoint meta, so a
    resumed run reports the same totals as an uninterrupted one (tested in
    tests/test_runtime.py) — dropped requests from pre-crash rounds are not
    forgotten, and the overlap-efficiency of the plan survives the restart.
    """

    def __init__(self, g: CSRGraph, cfg: Node2VecConfig,
                 mesh: Optional[Mesh] = None,
                 checkpointer: Optional[Checkpointer] = None):
        self.g = g
        self.cfg = cfg
        self.mesh = mesh
        self.ckpt = checkpointer
        # exact rounds must never drop: a dropped request silently skews the
        # corpus, so upgrade the engine's warning to an error (the engine is
        # the single owner of drop policy)
        plan = cfg.plan(mesh)
        if cfg.mode == "exact":
            plan = dataclasses.replace(plan, strict_drops=True)
        self.engine = WalkEngine.build(g, plan, mesh=mesh)
        self.round_stats: dict = {}   # round -> WalkStats (this process)
        self.total_dropped = 0        # cumulative, survives resume via meta
        self._pending_updates: list = []   # DeltaBatches queued mid-stream
        self.update_reports: list = []     # UpdateReport per drained queue

    def completed_rounds(self) -> int:
        if self.ckpt is None:
            return 0
        step = self.ckpt.latest_step()
        return 0 if step is None else step

    def run_round(self, r: int) -> np.ndarray:
        res = self.engine.run(seed=round_seed(self.cfg.seed, r))
        self.round_stats[r] = res.stats
        self.total_dropped += res.stats.dropped
        return res.walks

    def submit_update(self, deltas) -> None:
        """Queue edge deltas to land *between* rounds.

        Batches are drained after the next round is yielded and applied via
        ``WalkEngine.update`` (shard-local invalidation, no whole-graph
        rebuild). ``engine.rounds`` dispatches round ``r+1`` before round
        ``r`` finalizes, so an update submitted while consuming round ``r``
        first affects round ``r+2`` — bounded staleness of one in-flight
        round, and never a torn round (every round walks exactly one graph
        version; ``WalkStats.graph_version`` records which). Updates are
        not checkpointed: a resumed run replays rounds against the graph it
        reopens with.
        """
        batches = [deltas] if isinstance(deltas, DeltaBatch) else list(deltas)
        self._pending_updates.extend(batches)

    def _drain_updates(self) -> None:
        if not self._pending_updates:
            return
        batches, self._pending_updates = self._pending_updates, []
        self.update_reports.append(self.engine.update(batches))

    def stats_summary(self) -> dict:
        """Cumulative accounting across yielded rounds (including rounds
        restored from a checkpoint): total dropped requests plus the plan's
        exposed-vs-total collective bytes and overlap efficiency."""
        exposed = sum(s.exposed_collective_bytes
                      for s in self.round_stats.values())
        total = sum(s.collective_bytes for s in self.round_stats.values())
        return {"dropped": self.total_dropped,
                "exposed_collective_bytes": exposed,
                "collective_bytes": total,
                "overlap_efficiency":
                    1.0 - exposed / total if total else 0.0}

    def rounds(self) -> Iterator[np.ndarray]:
        start = self.completed_rounds()
        done = []
        if start and self.ckpt is not None:
            (prev,), meta = self.ckpt.restore((np.zeros(
                (start * self.g.n, self.cfg.walk_length), np.int32),))
            self.total_dropped = int((meta or {}).get("dropped", 0))
            done = [prev[i * self.g.n:(i + 1) * self.g.n]
                    for i in range(start)]
            for w in done:
                yield w
        # engine.rounds dispatches round r+1 before finalizing round r, so a
        # downstream consumer (the streaming SGNS trainer) trains on round r
        # while round r+1 walks — same per-round seeds as run_round(r)
        # (round_seed(cfg.seed, r)), so resumed runs stay bit-identical.
        live = self.engine.rounds(self.cfg.num_walks, seed=self.cfg.seed,
                                  start=start)
        for r, res in zip(range(start, self.cfg.num_walks), live):
            self.round_stats[r] = res.stats
            self.total_dropped += res.stats.dropped
            walks = res.walks
            done.append(walks)
            if self.ckpt is not None:
                s = self.round_stats[r]
                self.ckpt.save(r + 1, (np.concatenate(done, axis=0),),
                               meta={"round": r + 1,
                                     "dropped": self.total_dropped,
                                     "exposed_collective_bytes":
                                         s.exposed_collective_bytes,
                                     "overlap_efficiency":
                                         s.overlap_efficiency,
                                     "graph_version": s.graph_version},
                               blocking=False)
            yield walks
            self._drain_updates()
        if self.ckpt is not None:
            self.ckpt.wait()


def elastic_restart(g: CSRGraph, cfg: Node2VecConfig, ckpt: Checkpointer,
                    new_mesh: Optional[Mesh]) -> WalkRoundRunner:
    """Resume walk rounds on a *different* mesh (node failure / rescale).

    Nothing graph- or walk-related is device-count dependent: the sharded
    layout is rebuilt for the new shard count inside ``WalkEngine.build``
    and completed rounds are read back from the checkpoint.
    """
    return WalkRoundRunner(g, cfg, mesh=new_mesh, checkpointer=ckpt)
