"""Straggler / load-balance diagnostics for the BSP walk engine.

In a bulk-synchronous superstep the slowest shard sets the pace (the paper's
Fig. 13-14 story: skew -> heavy shards -> slow supersteps). Mitigations in
this framework are structural:

* degree cap + hot-cache: per-walker exact work is bounded by O(cap), and the
  heavy tail (d > cap) is served by replicated cache / O(1) alias draws, so
  no shard's compute scales with max degree;
* request capacity: per-destination all_to_all slots bound the serve load of
  any single shard;
* FN-Multi: fewer concurrent walkers per round bounds everything else.

This module *measures* the residual imbalance so deployments can check the
mitigations hold on their graph.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.graph import CSRGraph


@dataclasses.dataclass
class BalanceReport:
    shards: int
    edges_per_shard: np.ndarray
    hot_per_shard: np.ndarray
    capped_work_per_shard: np.ndarray

    @property
    def edge_imbalance(self) -> float:
        m = self.edges_per_shard.mean()
        return float(self.edges_per_shard.max() / m) if m else 1.0

    @property
    def capped_imbalance(self) -> float:
        """Imbalance of *bounded* per-step work (post cap+cache) — the number
        that actually sets BSP superstep time."""
        m = self.capped_work_per_shard.mean()
        return float(self.capped_work_per_shard.max() / m) if m else 1.0

    def to_dict(self) -> Dict:
        return {"shards": self.shards,
                "edge_imbalance": self.edge_imbalance,
                "capped_imbalance": self.capped_imbalance}


def shard_balance(g: CSRGraph, num_shards: int, cap: int) -> BalanceReport:
    """Range-partition diagnostics: raw edge imbalance vs post-cap work."""
    n_pad = ((g.n + num_shards - 1) // num_shards) * num_shards
    n_local = n_pad // num_shards
    deg = np.zeros(n_pad, np.int64)
    deg[:g.n] = g.deg
    per = deg.reshape(num_shards, n_local)
    edges = per.sum(axis=1)
    hot = (per > cap).sum(axis=1)
    capped = np.minimum(per, cap).sum(axis=1)
    return BalanceReport(shards=num_shards, edges_per_shard=edges,
                         hot_per_shard=hot, capped_work_per_shard=capped)
