"""Top-level model API shared by every assigned architecture.

    params            = init_params(cfg, key)
    loss              = loss_fn(cfg, params, batch)           (train)
    logits, caches    = prefill(cfg, params, batch)           (inference)
    logits, caches    = serve_step(cfg, params, token, pos, caches)

Batch layouts (see configs.input_specs):
  LM families:   {"tokens": [B, S] i32, "labels": [B, S] i32}
  encdec:        + {"frames": [B, Ta, D]}  (audio frontend stub: precomputed
                 frame embeddings, per the assignment spec)
  vlm:           + {"patches": [B, Ni, D]} (vision frontend stub)

The MoE group count is wired to the batch sharding factor so routing is
shard-local (see models/moe.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import transformer as tf
from repro.models.actsharding import constrain_batch, constrain_logits
from repro.models.config import ModelConfig
from repro.models.layers import (dtype_of, embed_tokens, init_embed,
                                 logits_out, softmax_xent)


# ---------------- init ----------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    k_embed, k_blocks, k_enc = jax.random.split(key, 3)
    params = {"embed": init_embed(cfg, k_embed),
              "blocks": tf.init_blocks(cfg, k_blocks)}
    if cfg.enc_layers:
        enc_cfg = encoder_config(cfg)
        params["encoder"] = tf.init_blocks(enc_cfg, k_enc)
    return params


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    """The encoder stack of an enc-dec model: bidirectional dense layers."""
    import dataclasses
    return dataclasses.replace(
        cfg, num_layers=cfg.enc_layers, attn_every=1, cross_every=0,
        moe_experts=0, moe_every=0, enc_layers=0)


def _memory(cfg: ModelConfig, params: Dict, batch: Dict
            ) -> Optional[jnp.ndarray]:
    """Cross-attention memory: encoder output (encdec) or patch embeddings
    (vlm). Frontends are stubs: inputs arrive as precomputed embeddings."""
    if cfg.enc_layers:
        frames = batch["frames"].astype(dtype_of(cfg))
        pos = jnp.arange(frames.shape[1])
        enc_cfg = encoder_config(cfg)
        return tf.stack_train(enc_cfg, params["encoder"], frames, pos,
                              causal=False)
    if cfg.cross_every:
        return batch["patches"].astype(dtype_of(cfg))
    return None


# ---------------- train ----------------

def forward_train(cfg: ModelConfig, params: Dict, batch: Dict,
                  num_groups: int = 1) -> jnp.ndarray:
    tokens = batch["tokens"]
    x = constrain_batch(embed_tokens(cfg, params["embed"], tokens))
    positions = jnp.arange(tokens.shape[1])
    memory = _memory(cfg, params, batch)
    x = tf.stack_train(cfg, params["blocks"], x, positions, memory=memory,
                       num_groups=num_groups)
    logits = logits_out(cfg, params["embed"], constrain_batch(x))
    return constrain_logits(logits)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict,
            num_groups: int = 1) -> jnp.ndarray:
    logits = forward_train(cfg, params, batch, num_groups)
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


# ---------------- inference ----------------

def prefill(cfg: ModelConfig, params: Dict, batch: Dict, max_len: int,
            num_groups: int = 1) -> Tuple[jnp.ndarray, Dict]:
    """Run the full prompt, returning (last-token logits, filled caches)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.arange(s)
    memory = _memory(cfg, params, batch)
    caches = tf.init_caches(cfg, b, max_len, dtype_of(cfg))
    x, caches = tf.stack_prefill(cfg, params["blocks"], caches, x, positions,
                                 memory=memory, num_groups=num_groups)
    logits = logits_out(cfg, params["embed"], x[:, -1:])
    return logits[:, 0], caches


def serve_step(cfg: ModelConfig, params: Dict, token: jnp.ndarray,
               pos: jnp.ndarray, caches: Dict, num_groups: int = 1
               ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: token [B] i32, pos scalar i32 -> (logits [B, V],
    updated caches). Sub-quadratic archs (ssm/hybrid/SWA) have O(state)
    cost independent of context length."""
    x = embed_tokens(cfg, params["embed"], token[:, None])
    x, caches = tf.stack_decode(cfg, params["blocks"], caches, x, pos,
                                num_groups=num_groups)
    logits = logits_out(cfg, params["embed"], x)
    return logits[:, 0], caches
