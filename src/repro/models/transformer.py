"""Stack assembler: every architecture is a ``lax.scan`` over superblocks.

A superblock is the repeating layer pattern from ``ModelConfig.superblock()``
(dense: 1 layer; jamba: 8 layers with 1 attention + 7 mamba and alternating
dense/MoE FFNs; llama-vision: 4 self-attn + 1 cross-attn; ...). Parameters
are stacked [NSB, ...] on the leading axis; scanning keeps HLO size and
compile time independent of depth and gives the standard remat boundary.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_lib
from repro.models.actsharding import constrain_batch
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import init_mlp, init_rms, mlp_apply, rms_norm


# ---------------- init ----------------

def init_layer(cfg: ModelConfig, spec: LayerSpec, key: jax.Array) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"pre_norm": init_rms(cfg)}
    if spec.kind in ("attn", "cross_attn", "attn_cross"):
        p["attn"] = attn.init_attn(cfg, k1)
        if spec.kind == "attn_cross":
            p["xattn"] = attn.init_attn(cfg, k3)
            p["xnorm"] = init_rms(cfg)
    else:
        p["mamba"] = mb.init_mamba(cfg, k1)
    if spec.ffn != "none":
        p["post_norm"] = init_rms(cfg)
        p["ffn"] = (moe_lib.init_moe(cfg, k2) if spec.ffn == "moe"
                    else init_mlp(cfg, k2))
    return p


def init_blocks(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Stacked per-pattern-position params: {"l0": stacked, "l1": ...}."""
    pattern = cfg.superblock()
    nsb = cfg.num_superblocks
    out = {}
    for i, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), nsb)
        per = [init_layer(cfg, spec, keys[j]) for j in range(nsb)]
        out[f"l{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return out


# ---------------- one superblock ----------------

def _ffn(cfg: ModelConfig, spec: LayerSpec, p, x, num_groups: int):
    if spec.ffn == "none":
        return x
    h = rms_norm(x, p["post_norm"])
    if spec.ffn == "moe":
        return x + moe_lib.moe_apply(cfg, p["ffn"], h, num_groups)
    return x + mlp_apply(cfg, p["ffn"], h)


def superblock_train(cfg: ModelConfig, params_sb: Dict, x: jnp.ndarray,
                     positions: jnp.ndarray, memory: Optional[jnp.ndarray],
                     num_groups: int, causal: bool = True) -> jnp.ndarray:
    for i, spec in enumerate(cfg.superblock()):
        p = params_sb[f"l{i}"]
        x = constrain_batch(x)  # keep batch sharded; gather weights (ZeRO-3)
        h = rms_norm(x, p["pre_norm"])
        if spec.kind in ("attn", "attn_cross"):
            x = x + attn.attn_train(cfg, p["attn"], h, positions,
                                    causal=causal)
            if spec.kind == "attn_cross":
                hx = rms_norm(x, p["xnorm"])
                x = x + attn.attn_train(cfg, p["xattn"], hx, positions,
                                        memory=memory)
        elif spec.kind == "cross_attn":
            x = x + attn.attn_train(cfg, p["attn"], h, positions,
                                    memory=memory)
        else:
            x = x + mb.mamba_apply(cfg, p["mamba"], h)
        x = _ffn(cfg, spec, p, x, num_groups)
    return x


def stack_train(cfg: ModelConfig, blocks: Dict, x: jnp.ndarray,
                positions: jnp.ndarray, memory: Optional[jnp.ndarray] = None,
                num_groups: int = 1, causal: bool = True) -> jnp.ndarray:
    def body(carry, params_sb):
        out = superblock_train(cfg, params_sb, carry, positions, memory,
                               num_groups, causal)
        return out, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if not cfg.scan_layers:
        for j in range(cfg.num_superblocks):
            x, _ = body(x, jax.tree.map(lambda t: t[j], blocks))
        return x
    x, _ = jax.lax.scan(body, x, blocks)
    return x


# ---------------- caches ----------------

def _memory_len(cfg: ModelConfig) -> int:
    return (cfg.num_audio_frames if cfg.enc_layers else cfg.num_image_tokens)


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype) -> Dict:
    if spec.kind == "attn":
        return attn.init_cache(cfg, batch, max_len, dtype)
    if spec.kind in ("cross_attn", "attn_cross"):
        # cross-attn k/v over the (image/encoder) memory, filled at prefill
        shape = (batch, _memory_len(cfg), cfg.num_kv_heads, cfg.head_dim)
        c = {"mk": jnp.zeros(shape, dtype), "mv": jnp.zeros(shape, dtype)}
        if spec.kind == "attn_cross":
            c.update(attn.init_cache(cfg, batch, max_len, dtype))
        return c
    return mb.init_mamba_cache(cfg, batch, dtype)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    nsb = cfg.num_superblocks
    out = {}
    for i, spec in enumerate(cfg.superblock()):
        one = init_layer_cache(cfg, spec, batch, max_len, dtype)
        out[f"l{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (nsb,) + x.shape), one)
    return out


# ---------------- decode ----------------

def superblock_decode(cfg: ModelConfig, params_sb: Dict, cache_sb: Dict,
                      x: jnp.ndarray, pos: jnp.ndarray,
                      num_groups: int) -> Tuple[jnp.ndarray, Dict]:
    new_cache = {}
    for i, spec in enumerate(cfg.superblock()):
        p = params_sb[f"l{i}"]
        c = cache_sb[f"l{i}"]
        h = rms_norm(x, p["pre_norm"])
        if spec.kind in ("attn", "attn_cross"):
            kv = ({"k": c["k"], "v": c["v"]} if spec.kind == "attn_cross"
                  else c)
            o, kv = attn.attn_decode(cfg, p["attn"], h, pos, kv)
            x = x + o
            if spec.kind == "attn_cross":
                hx = rms_norm(x, p["xnorm"])
                x = x + attn.cross_decode(cfg, p["xattn"], hx,
                                          (c["mk"], c["mv"]))
                c = {"mk": c["mk"], "mv": c["mv"], **kv}
            else:
                c = kv
        elif spec.kind == "cross_attn":
            x = x + attn.cross_decode(cfg, p["attn"], h, (c["mk"], c["mv"]))
        else:
            o, c = mb.mamba_decode(cfg, p["mamba"], h, c)
            x = x + o
        new_cache[f"l{i}"] = c
        x = _ffn(cfg, spec, p, x, num_groups)
    return x, new_cache


def stack_decode(cfg: ModelConfig, blocks: Dict, caches: Dict, x: jnp.ndarray,
                 pos: jnp.ndarray, num_groups: int = 1
                 ) -> Tuple[jnp.ndarray, Dict]:
    def body(carry, scanned):
        params_sb, cache_sb = scanned
        out, new_cache = superblock_decode(cfg, params_sb, cache_sb, carry,
                                           pos, num_groups)
        return out, new_cache

    if not cfg.scan_layers:
        ncs = []
        for j in range(cfg.num_superblocks):
            x, nc = body(x, jax.tree.map(lambda t: t[j], (blocks, caches)))
            ncs.append(nc)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    return x, new_caches


# ---------------- prefill ----------------

def superblock_prefill(cfg: ModelConfig, params_sb: Dict, cache_sb: Dict,
                       x: jnp.ndarray, positions: jnp.ndarray,
                       memory: Optional[jnp.ndarray],
                       num_groups: int) -> Tuple[jnp.ndarray, Dict]:
    new_cache = {}
    for i, spec in enumerate(cfg.superblock()):
        p = params_sb[f"l{i}"]
        c = cache_sb[f"l{i}"]
        h = rms_norm(x, p["pre_norm"])
        if spec.kind in ("attn", "attn_cross"):
            kv = ({"k": c["k"], "v": c["v"]} if spec.kind == "attn_cross"
                  else c)
            o, kv = attn.attn_prefill(cfg, p["attn"], h, positions, kv)
            x = x + o
            if spec.kind == "attn_cross":
                mk, mv = attn.memory_kv(cfg, p["xattn"], memory)
                hx = rms_norm(x, p["xnorm"])
                x = x + attn.cross_decode(cfg, p["xattn"], hx, (mk, mv))
                c = {"mk": mk.astype(c["mk"].dtype),
                     "mv": mv.astype(c["mv"].dtype), **kv}
            else:
                c = kv
        elif spec.kind == "cross_attn":
            mk, mv = attn.memory_kv(cfg, p["attn"], memory)
            c = {"mk": mk.astype(c["mk"].dtype),
                 "mv": mv.astype(c["mv"].dtype)}
            x = x + attn.cross_decode(cfg, p["attn"], h, (mk, mv))
        else:
            o, c = mb.mamba_prefill(cfg, p["mamba"], h)
            x = x + o
        new_cache[f"l{i}"] = c
        x = _ffn(cfg, spec, p, x, num_groups)
    return x, new_cache


def stack_prefill(cfg: ModelConfig, blocks: Dict, caches: Dict,
                  x: jnp.ndarray, positions: jnp.ndarray,
                  memory: Optional[jnp.ndarray] = None,
                  num_groups: int = 1) -> Tuple[jnp.ndarray, Dict]:
    def body(carry, scanned):
        params_sb, cache_sb = scanned
        out, new_cache = superblock_prefill(cfg, params_sb, cache_sb, carry,
                                            positions, memory, num_groups)
        return out, new_cache

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if not cfg.scan_layers:
        ncs = []
        for j in range(cfg.num_superblocks):
            x, nc = body(x, jax.tree.map(lambda t: t[j], (blocks, caches)))
            ncs.append(nc)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    return x, new_caches
