"""Architecture configuration for the model zoo.

One frozen dataclass covers all 10 assigned families; the block layout is
expressed as a *superblock pattern* (list of layer descriptors) repeated
``num_layers / len(pattern)`` times — every architecture becomes a
``lax.scan`` over superblocks, which keeps HLO size and compile time flat in
depth (MaxText-style scanned layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a superblock."""
    kind: str          # "attn" | "mamba" | "cross_attn"
    ffn: str           # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    mlp_act: str = "swiglu"     # swiglu | sq_relu | gelu
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_every: int = 0          # within a superblock: layer i is MoE if
                                # moe_every and i % moe_every == moe_phase
    moe_phase: int = 0
    capacity_factor: float = 1.25
    # --- attention ---
    window: int = 0             # sliding-window size; 0 = full causal
    rope_theta: float = 1e4
    attn_logit_softcap: float = 0.0
    # --- hybrid / ssm ---
    attn_every: int = 1         # 1 = all attn; 8 = jamba (1 attn per 8);
                                # 0 = attention-free (mamba)
    attn_offset: int = 4        # index of the attn layer inside the period
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # --- enc-dec ---
    enc_layers: int = 0         # >0 => encoder-decoder (num_layers = decoder)
    # --- vlm ---
    cross_every: int = 0        # period of cross-attn layers (llama-vision 5)
    num_image_tokens: int = 1600
    num_audio_frames: int = 1024
    # --- numerics / training ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True    # False: unroll superblocks (used by the
                                # dry-run cost extrapolation; see roofline)
    tie_embeddings: bool = False
    # long-context capability marker (sub-quadratic decode path exists)
    subquadratic: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def superblock(self) -> Tuple[LayerSpec, ...]:
        """The repeating layer pattern."""
        period = (self.attn_every if self.attn_every > 1 else
                  (self.cross_every if self.cross_every else 1))
        specs = []
        for i in range(period):
            if self.attn_every == 0:
                kind = "mamba"
            elif self.attn_every == 1:
                kind = "attn"
            else:  # hybrid: one attn layer per period at attn_offset
                kind = "attn" if i == self.attn_offset % period else "mamba"
            if self.enc_layers and self.cross_every == 1:
                kind = "attn_cross"  # enc-dec decoder: self + cross per layer
            elif self.cross_every and i == period - 1:
                kind = "cross_attn"
            if self.family == "ssm":
                ffn = "none"
            elif self.moe_experts and (self.moe_every == 1 or (
                    self.moe_every and i % self.moe_every == self.moe_phase)):
                ffn = "moe"
            else:
                ffn = "dense"
            specs.append(LayerSpec(kind=kind, ffn=ffn))
        assert self.num_layers % len(specs) == 0, (self.num_layers, specs)
        return tuple(specs)

    @property
    def num_superblocks(self) -> int:
        return self.num_layers // len(self.superblock())

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        n = 0
        v_embed = self.vocab * self.d_model
        n += v_embed * (1 if self.tie_embeddings else 2)
        for spec in self.superblock():
            n_layer = 0
            if spec.kind in ("attn", "cross_attn", "attn_cross"):
                qkv = self.d_model * self.head_dim * (
                    self.num_heads + 2 * self.num_kv_heads)
                out = self.num_heads * self.head_dim * self.d_model
                n_layer += qkv + out
                if spec.kind == "attn_cross":  # second (cross) attention
                    n_layer += qkv + out
            if spec.kind == "mamba":
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
                # in_proj: z, x, B, C, dt ; out_proj
                n_layer += self.d_model * (2 * di + 2 * ds + nh)
                n_layer += di * self.d_model
                n_layer += self.ssm_conv * (di + 2 * ds)
            if spec.ffn == "dense":
                mats = 3 if self.mlp_act == "swiglu" else 2
                n_layer += mats * self.d_model * self.d_ff
            elif spec.ffn == "moe":
                mats = 3 if self.mlp_act == "swiglu" else 2
                n_layer += (self.moe_experts * mats * self.d_model * self.d_ff
                            + self.d_model * self.moe_experts)
            n_layer += 2 * self.d_model  # norms
            n += n_layer * self.num_superblocks
        if self.enc_layers:
            enc = self.enc_layers * (
                self.d_model * self.head_dim * (self.num_heads +
                                                2 * self.num_kv_heads)
                + self.num_heads * self.head_dim * self.d_model
                + (3 if self.mlp_act == "swiglu" else 2) * self.d_model *
                self.d_ff + 2 * self.d_model)
            n += enc
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of experts), for 6·N_active·D."""
        if not self.moe_experts:
            return self.param_count()
        full = self.param_count()
        mats = 3 if self.mlp_act == "swiglu" else 2
        moe_layers = sum(1 for s in self.superblock()
                         if s.ffn == "moe") * self.num_superblocks
        expert_params = moe_layers * self.moe_experts * mats * \
            self.d_model * self.d_ff
        active = moe_layers * self.moe_top_k * mats * self.d_model * self.d_ff
        return full - expert_params + active
