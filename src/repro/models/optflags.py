"""Beyond-paper optimization flags (env-controlled so the §Perf hillclimb can
A/B each change against the committed baseline; defaults flip to ON once a
win is confirmed in EXPERIMENTS.md §Perf).

    REPRO_SEQ_DECODE=1   seq-sharded partial-softmax decode attention:
                         keeps the KV cache sharded over `model` through the
                         attention einsums (psum of tiny softmax stats)
                         instead of all-gathering the cache every token.
Note on bf16 TP collectives: the residual psums lower as bf16 already (the
einsums are bf16); the f32 all-reduces seen in this container's HLO are a
CPU-backend upcast artifact (isolated repro in EXPERIMENTS.md methodology
note 4), so there is nothing to flip at the program level — on TPU the
collectives are natively bf16.
"""
from __future__ import annotations

import os


def _flag(name: str, default: str = "0") -> bool:
    return os.environ.get(name, default) == "1"


SEQ_DECODE = _flag("REPRO_SEQ_DECODE", "1")   # default ON (confirmed win:
                                              # 81x decode collective cut)
