"""Activation sharding constraints (MaxText-style).

Without explicit constraints GSPMD may resolve the FSDP-weight/batch axis
conflict by *replicating activations* and all-reducing them (observed: f32
[B, S, F/tp] all-reduces of the full global batch — hundreds of GB per step).
Constraining activations to stay batch-sharded forces the partitioner to
all-gather the (much smaller) weights instead — the ZeRO-3 pattern.

The launcher installs the mesh via ``set_act_mesh``; model code calls
``constrain`` unconditionally — it is a no-op when no mesh is installed
(single-device smoke tests) so the model stays mesh-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_WEIGHT_CONSTRAIN = True


def set_act_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def set_weight_constrain(enabled: bool) -> None:
    """Serve mode stores params in their use layout (TP-only / EP), so the
    ZeRO-3 gather-at-use constraint must be disabled there — it would undo
    expert parallelism by requesting a gathered expert stack."""
    global _WEIGHT_CONSTRAIN
    _WEIGHT_CONSTRAIN = enabled


def _batch_axes():
    return tuple(a for a in ("pod", "data") if a in _MESH.shape)


def constrain_batch(x, batch_divisible: bool = True):
    """x: [B, ...] -> batch over (pod, data), rest unconstrained... i.e.
    replicated-or-propagated? No: constraint pins only what we name; we pin
    the batch dim and leave feature dims to the propagator via None."""
    if _MESH is None or not batch_divisible:
        return x
    ba = _batch_axes()
    import numpy as np
    nb = int(np.prod([_MESH.shape[a] for a in ba]))
    if x.shape[0] % max(nb, 1) != 0:
        return x
    spec = P(ba, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain_weight(w, dims):
    """ZeRO-3 'gather at use': weights are *stored* sharded over
    (data=fsdp, model=tp) but must be *used* in their TP-only layout —
    otherwise GSPMD may satisfy the fsdp contraction by replicating the
    (huge) activations instead of gathering the (small) weight. ``dims`` is a
    tuple of "model"/None per weight dim; "model" entries are kept only when
    divisible."""
    if _MESH is None or not _WEIGHT_CONSTRAIN:
        return w
    tp = _MESH.shape.get("model", 1)
    spec = P(*("model" if d == "model" and s % tp == 0 else None
               for d, s in zip(dims, w.shape)))
    return jax.lax.with_sharding_constraint(w, NamedSharding(_MESH, spec))


def constrain_decode_scores(x):
    """Decode attention scores [B, H, 1, S]: keep the cache-sequence dim
    sharded over `model` so softmax lowers to partial-softmax + tiny psums
    instead of an all-gather of the cache (see optflags.SEQ_DECODE)."""
    if _MESH is None:
        return x
    ba = _batch_axes()
    import numpy as np
    nb = int(np.prod([_MESH.shape[a] for a in ba]))
    b_ok = x.shape[0] % max(nb, 1) == 0
    s_ok = x.shape[-1] % _MESH.shape["model"] == 0
    spec = P(ba if b_ok else None, None, None, "model" if s_ok else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain_logits(x, vocab_axis: str = "model"):
    """logits [..., V]: batch over (pod, data), vocab over model if even."""
    if _MESH is None:
        return x
    ba = _batch_axes()
    import numpy as np
    nb = int(np.prod([_MESH.shape[a] for a in ba]))
    b_ok = x.shape[0] % max(nb, 1) == 0
    v_ok = x.shape[-1] % _MESH.shape[vocab_axis] == 0
    spec = P(ba if b_ok else None, *([None] * (x.ndim - 2)),
             vocab_axis if v_ok else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
