"""Shared neural building blocks (pure functional, explicit param pytrees)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import actsharding
from repro.models.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(
        jnp.float32))).astype(dt)


def init_rms(cfg: ModelConfig):
    return jnp.zeros((cfg.d_model,), pdtype_of(cfg))


# ---------------- rotary embeddings ----------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [..., S, H, dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------- MLP ----------------

def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    pd = pdtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    scale = cfg.d_model ** -0.5
    p = {"down": jax.random.normal(k3, (d_ff, cfg.d_model), pd) *
         d_ff ** -0.5}
    if cfg.mlp_act == "swiglu":
        p["gate"] = jax.random.normal(k1, (cfg.d_model, d_ff), pd) * scale
        p["up"] = jax.random.normal(k2, (cfg.d_model, d_ff), pd) * scale
    else:
        p["up"] = jax.random.normal(k2, (cfg.d_model, d_ff), pd) * scale
    return p


def mlp_apply(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray):
    dt = x.dtype
    cw = actsharding.constrain_weight
    up = cw(p["up"].astype(dt), (None, "model"))
    if cfg.mlp_act == "swiglu":
        g = x @ cw(p["gate"].astype(dt), (None, "model"))
        h = jax.nn.silu(g) * (x @ up)
    elif cfg.mlp_act == "sq_relu":   # nemotron: squared ReLU
        h = jnp.square(jax.nn.relu(x @ up))
    else:
        h = jax.nn.gelu(x @ up)
    return h @ cw(p["down"].astype(dt), ("model", None))


# ---------------- embeddings / unembedding ----------------

def init_embed(cfg: ModelConfig, key: jax.Array):
    pd = pdtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), pd) * 0.02,
         "final_norm": init_rms(cfg)}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(
            k2, (cfg.vocab, cfg.d_model), pd) * cfg.d_model ** -0.5
    return p


def embed_tokens(cfg: ModelConfig, p, tokens: jnp.ndarray):
    w = actsharding.constrain_weight(p["tok"].astype(dtype_of(cfg)),
                                     ("model", None))
    return w[tokens]


def logits_out(cfg: ModelConfig, p, x: jnp.ndarray):
    """Final norm + unembed; logits in f32 for a stable softmax."""
    x = rms_norm(x, p["final_norm"])
    w = (p["tok"] if cfg.tie_embeddings else p["unembed"])
    w = actsharding.constrain_weight(w.astype(jnp.float32), ("model", None))
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), w)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None):
    """Token-mean cross entropy. logits [..., V] f32, labels [...] i32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom
