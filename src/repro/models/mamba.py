"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan and
O(1)-state recurrent decode.

Train path: the sequence is split into chunks of ``CHUNK``; within a chunk the
SSD dual form is an attention-like [Q, Q] masked matmul (MXU-friendly), and a
``lax.scan`` carries the [B, H, hd, ds] state across chunks — compute is
O(S·Q) instead of O(S^2), and the scan keeps HLO size flat.

Decode path: h <- exp(A·dt)·h + dt·B⊗x per token — this is what makes the
ssm/hybrid architectures serve long_500k with a fixed-size state.

Sharding: the input projection is SPLIT per component (z, x, B, C, dt) so
every output is shard-aligned on the ``model`` axis — a fused [D, 2di+2ds+nh]
projection shards at 274-column boundaries that cut across the component
splits and forces collective-permute resharding on every slice (measured:
~0.3 GiB/layer in the 32k prefill dry-run; see EXPERIMENTS.md §Perf). Heads
(z, x, dt) are tensor-parallel; B/C are head-shared (ngroups=1) and therefore
replicated — their projections are negligible (D x 128).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.actsharding import constrain_weight
from repro.models.config import ModelConfig
from repro.models.layers import pdtype_of, rms_norm

CHUNK = 256


def init_mamba(cfg: ModelConfig, key: jax.Array):
    pd = pdtype_of(cfg)
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_z": jax.random.normal(ks[0], (d, di), pd) * s,
        "in_x": jax.random.normal(ks[1], (d, di), pd) * s,
        "in_b": jax.random.normal(ks[2], (d, ds), pd) * s,
        "in_c": jax.random.normal(ks[3], (d, ds), pd) * s,
        "in_dt": jax.random.normal(ks[4], (d, nh), pd) * s,
        "conv_x": jax.random.normal(ks[5], (cfg.ssm_conv, di), pd) * 0.1,
        "conv_b": jax.random.normal(
            jax.random.fold_in(key, 7), (cfg.ssm_conv, ds), pd) * 0.1,
        "conv_c": jax.random.normal(
            jax.random.fold_in(key, 8), (cfg.ssm_conv, ds), pd) * 0.1,
        "conv_bias_x": jnp.zeros((di,), pd),
        "conv_bias_b": jnp.zeros((ds,), pd),
        "conv_bias_c": jnp.zeros((ds,), pd),
        "A_log": jnp.zeros((nh,), pd),
        "dt_bias": jnp.zeros((nh,), pd),
        "D": jnp.ones((nh,), pd),
        "norm": jnp.zeros((di,), pd),
        "out_proj": jax.random.normal(
            jax.random.fold_in(key, 9), (di, d), pd) * di ** -0.5,
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv along S. x [B, S, C], w [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # small static K (4): unrolled taps
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _project(cfg: ModelConfig, p: Dict, x: jnp.ndarray):
    """Split, shard-aligned input projections. Returns (z, xr, br, cr, dt)
    where xr/br/cr are pre-conv raw streams."""
    dt_ = x.dtype
    z = x @ constrain_weight(p["in_z"].astype(dt_), (None, "model"))
    xr = x @ constrain_weight(p["in_x"].astype(dt_), (None, "model"))
    br = x @ constrain_weight(p["in_b"].astype(dt_), (None, None))
    cr = x @ constrain_weight(p["in_c"].astype(dt_), (None, None))
    dtr = x @ constrain_weight(p["in_dt"].astype(dt_), (None, "model"))
    return z, xr, br, cr, dtr


def _ssd_chunk_scan(cfg: ModelConfig, xh: jnp.ndarray, bmat: jnp.ndarray,
                    cmat: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                    h0: jnp.ndarray):
    """One chunk of the SSD dual form.

    xh [B,Q,H,hd]; bmat/cmat [B,Q,ds]; dt [B,Q,H]; a = A*dt (negative)
    h0 [B,H,hd,ds]. Returns (y [B,Q,H,hd], h1).
    """
    lc = jnp.cumsum(a, axis=1)                     # [B,Q,H] log decay cumsum
    # intra-chunk: M[t,i] = (C_t.B_i) * exp(lc_t - lc_i) * dt_i  (t >= i)
    cb = jnp.einsum("bts,bis->bti", cmat, bmat)    # [B,Q,Q]
    q = xh.shape[1]
    causal = jnp.tril(jnp.ones((q, q), bool))
    ratio = jnp.exp(lc[:, :, None, :] - lc[:, None, :, :])   # [B,Q,Q,H]
    m = cb[..., None] * ratio * dt[:, None, :, :]
    m = jnp.where(causal[None, :, :, None], m, 0.0)
    y = jnp.einsum("btih,bihd->bthd", m.astype(xh.dtype), xh)
    # inter-chunk: y += exp(lc_t) * C_t . h0
    p = jnp.exp(lc)                                # [B,Q,H]
    y = y + jnp.einsum("bts,bhds->bthd", cmat,
                       h0.astype(xh.dtype)) * p[..., None].astype(xh.dtype)
    # state update: h1 = exp(lc_Q) h0 + sum_i exp(lc_Q - lc_i) dt_i B_i (x) x_i
    pq = jnp.exp(lc[:, -1])                        # [B,H]
    coef = jnp.exp(lc[:, -1:, :] - lc) * dt        # [B,Q,H]
    h_new = jnp.einsum("bqs,bqhd,bqh->bhds", bmat.astype(jnp.float32),
                       xh.astype(jnp.float32), coef.astype(jnp.float32))
    h1 = pq[:, :, None, None] * h0 + h_new
    return y, h1


def mamba_apply(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                return_state: bool = False):
    """Full-sequence (train / prefill) forward. x [B, S, D]."""
    dt_ = x.dtype
    b, s, _ = x.shape
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xr, br, cr, dtr = _project(cfg, p, x)
    xc = _causal_conv(xr, p["conv_x"].astype(dt_),
                      p["conv_bias_x"].astype(dt_))
    bmat = _causal_conv(br, p["conv_b"].astype(dt_),
                        p["conv_bias_b"].astype(dt_))
    cmat = _causal_conv(cr, p["conv_c"].astype(dt_),
                        p["conv_bias_c"].astype(dt_))
    dt = jax.nn.softplus(dtr.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # [B,S,H]
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt    # [B,S,H]
    xh = xc.reshape(b, s, nh, hd)

    q = min(CHUNK, s)
    assert s % q == 0, (s, q)
    nchunk = s // q

    def chunk_body(h, args):
        xq, bq, cq, dtq, aq = args
        y, h = _ssd_chunk_scan(cfg, xq, bq, cq, dtq, aq, h)
        return h, y

    def to_chunks(t):
        return t.reshape(b, nchunk, q, *t.shape[2:]).swapaxes(0, 1)

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk_body, h0,
                             (to_chunks(xh), to_chunks(bmat), to_chunks(cmat),
                              to_chunks(dt), to_chunks(a_neg)))
    y = ys.swapaxes(0, 1).reshape(b, s, nh, hd)
    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = y @ constrain_weight(p["out_proj"].astype(dt_), ("model", None))
    if return_state:
        # conv caches hold the last K-1 *pre-conv* rows of each stream
        k1 = cfg.ssm_conv - 1
        return out, {"h": h_fin, "cx": xr[:, -k1:, :], "cb": br[:, -k1:, :],
                     "cc": cr[:, -k1:, :]}
    return out


def mamba_prefill(cfg: ModelConfig, p: Dict, x: jnp.ndarray):
    """Full-sequence forward returning (y, cache) for subsequent decode."""
    return mamba_apply(cfg, p, x, return_state=True)


# ---------------- decode ----------------

def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    k1 = cfg.ssm_conv - 1
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                        cfg.ssm_state), jnp.float32),
        "cx": jnp.zeros((batch, k1, cfg.d_inner), dtype),
        "cb": jnp.zeros((batch, k1, cfg.ssm_state), dtype),
        "cc": jnp.zeros((batch, k1, cfg.ssm_state), dtype),
    }


def _conv_step(hist: jnp.ndarray, new: jnp.ndarray, w: jnp.ndarray,
               b: jnp.ndarray):
    """One causal-conv step: hist [B, K-1, C] + new [B, C]."""
    full = jnp.concatenate([hist, new[:, None, :]], axis=1)
    out = jnp.sum(full * w[None], axis=1) + b
    return jax.nn.silu(out), full[:, 1:, :]


def mamba_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step. x [B, 1, D]."""
    dt_ = x.dtype
    b = x.shape[0]
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xr, br, cr, dtr = _project(cfg, p, x[:, 0:1])
    z, xr, br, cr, dtr = (t[:, 0] for t in (z, xr, br, cr, dtr))
    xc, ncx = _conv_step(cache["cx"], xr, p["conv_x"].astype(dt_),
                         p["conv_bias_x"].astype(dt_))
    bvec, ncb = _conv_step(cache["cb"], br, p["conv_b"].astype(dt_),
                           p["conv_bias_b"].astype(dt_))
    cvec, ncc = _conv_step(cache["cc"], cr, p["conv_c"].astype(dt_),
                           p["conv_bias_c"].astype(dt_))
    dt = jax.nn.softplus(dtr.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # [B,H]
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)  # [B,H]
    xh = xc.reshape(b, nh, hd).astype(jnp.float32)
    upd = jnp.einsum("bhd,bs,bh->bhds", xh, bvec.astype(jnp.float32), dt)
    h = a[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bhds,bs->bhd", h, cvec.astype(jnp.float32))
    y = (y + xh * p["D"].astype(jnp.float32)[None, :, None]).astype(dt_)
    y = y.reshape(b, cfg.d_inner)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = (y @ constrain_weight(p["out_proj"].astype(dt_),
                                ("model", None)))[:, None, :]
    return out, {"h": h, "cx": ncx, "cb": ncb, "cc": ncc}
