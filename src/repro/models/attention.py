"""GQA attention: training (causal / sliding-window / bidirectional / cross)
and single-token decode against a KV cache.

Decode cache layout: k/v [B, S_max, KV, dh] with the *sequence* dim sharded
over the ``model`` mesh axis for long contexts (see launch/mesh.py sharding
rules) — partial-softmax reductions over the sharded axis are inserted by
GSPMD. Sliding-window archs (mixtral) use a ring buffer of size ``window`` so
decode cost is O(window), which is what makes long_500k serveable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import optflags
from repro.models.actsharding import constrain_decode_scores, constrain_weight
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, pdtype_of


def _wq(p, dt):
    return constrain_weight(p["wq"].astype(dt), (None, "model", None))


def _wkv(p, name, dt):
    return constrain_weight(p[name].astype(dt), (None, "model", None))


def _wo(p, dt):
    return constrain_weight(p["wo"].astype(dt), ("model", None, None))


def init_attn(cfg: ModelConfig, key: jax.Array):
    pd = pdtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = cfg.d_model ** -0.5
    return {
        "wq": jax.random.normal(
            k1, (cfg.d_model, cfg.num_heads, cfg.head_dim), pd) * s,
        "wk": jax.random.normal(
            k2, (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), pd) * s,
        "wv": jax.random.normal(
            k3, (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), pd) * s,
        "wo": jax.random.normal(
            k4, (cfg.num_heads, cfg.head_dim, cfg.d_model), pd) *
        (cfg.num_heads * cfg.head_dim) ** -0.5,
    }


def _expand_kv(k: jnp.ndarray, q_per_kv: int):
    """[B, S, KV, dh] -> [B, S, KV*q_per_kv, dh] by repeat (GQA)."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _mask_bias(sq: int, skv: int, causal: bool, window: int,
               q_offset: int = 0) -> jnp.ndarray:
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           bias: Optional[jnp.ndarray], softcap: float = 0.0) -> jnp.ndarray:
    """q [B,Sq,H,dh], k/v [B,Skv,H,dh] -> [B,Sq,H,dh]; f32 softmax."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attn_train(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
               positions: jnp.ndarray, causal: bool = True,
               window: Optional[int] = None,
               memory: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention. ``memory`` switches to cross-attention
    (k/v from memory, no mask, no rope on kv)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, _wq(p, dt))
    src = memory if memory is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, _wkv(p, "wk", dt))
    v = jnp.einsum("bsd,dhk->bshk", src, _wkv(p, "wv", dt))
    if memory is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        win = cfg.window if window is None else window
        bias = _mask_bias(x.shape[1], src.shape[1], causal, win)
    else:
        bias = None
    k = _expand_kv(k, cfg.q_per_kv)
    v = _expand_kv(v, cfg.q_per_kv)
    o = attend(q, k, v, bias, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", o, _wo(p, dt))


# ---------------- decode with KV cache ----------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype) -> Dict[str, jnp.ndarray]:
    length = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray, pos: jnp.ndarray,
                cache: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode. x [B, 1, D]; pos scalar i32 (current position).
    Sliding-window caches are ring buffers indexed ``pos % window``."""
    dt = x.dtype
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, _wq(p, dt))
    k_new = jnp.einsum("bsd,dhk->bshk", x, _wkv(p, "wk", dt))
    v_new = jnp.einsum("bsd,dhk->bshk", x, _wkv(p, "wv", dt))
    q = apply_rope(q, pos[None, None].astype(jnp.int32), cfg.rope_theta)
    k_new = apply_rope(k_new, pos[None, None].astype(jnp.int32),
                       cfg.rope_theta)
    s_cache = cache["k"].shape[1]
    slot = jnp.where(cfg.window > 0, pos % s_cache, pos)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                     (0, slot.astype(jnp.int32), 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                     (0, slot.astype(jnp.int32), 0, 0))
    # valid positions: <= pos (ring buffer: all slots written once full)
    kpos = jnp.arange(s_cache)
    if cfg.window:
        valid = (kpos <= slot) | (pos >= s_cache)
    else:
        valid = kpos <= pos
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, None, None]
    kk = _expand_kv(k, cfg.q_per_kv)
    vv = _expand_kv(v, cfg.q_per_kv)
    if optflags.SEQ_DECODE:
        # seq-sharded partial-softmax decode: keep the cache's S dim sharded
        # through the score einsum (GSPMD reduces softmax stats with tiny
        # psums) instead of all-gathering the cache per token.
        scale = q.shape[-1] ** -0.5
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(
            jnp.float32) * scale
        if cfg.attn_logit_softcap:
            scores = cfg.attn_logit_softcap * jnp.tanh(
                scores / cfg.attn_logit_softcap)
        scores = constrain_decode_scores(scores + bias)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    else:
        o = attend(q, kk, vv, bias, cfg.attn_logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, _wo(p, dt))
    return out, {"k": k, "v": v}


def attn_prefill(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                 positions: jnp.ndarray, cache: Dict[str, jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward that also fills the KV cache (SWA: last
    ``window`` entries at their ring slots)."""
    dt = x.dtype
    s = x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, _wq(p, dt))
    k = jnp.einsum("bsd,dhk->bshk", x, _wkv(p, "wk", dt))
    v = jnp.einsum("bsd,dhk->bshk", x, _wkv(p, "wv", dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    bias = _mask_bias(s, s, True, cfg.window)
    o = attend(q, _expand_kv(k, cfg.q_per_kv), _expand_kv(v, cfg.q_per_kv),
               bias, cfg.attn_logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, _wo(p, dt))
    s_cache = cache["k"].shape[1]
    if cfg.window and s > s_cache:
        tail = jnp.arange(s - s_cache, s)
        slots = tail % s_cache
        ck = cache["k"].at[:, slots].set(k[:, -s_cache:].astype(
            cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(v[:, -s_cache:].astype(
            cache["v"].dtype))
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k[:, :s_cache].astype(cache["k"].dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v[:, :s_cache].astype(cache["v"].dtype), (0, 0, 0, 0))
    return out, {"k": ck, "v": cv}


def cross_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                 memory_kv: Tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
    """Cross-attention during decode: k/v precomputed from the encoder/vision
    memory once at prefill."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, _wq(p, dt))
    k, v = memory_kv
    o = attend(q, _expand_kv(k, cfg.q_per_kv), _expand_kv(v, cfg.q_per_kv),
               None, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", o, _wo(p, dt))


def memory_kv(cfg: ModelConfig, p: Dict, memory: jnp.ndarray):
    dt = memory.dtype
    k = jnp.einsum("bsd,dhk->bshk", memory, _wkv(p, "wk", dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, _wkv(p, "wv", dt))
    return k, v
