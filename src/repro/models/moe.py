"""Mixture-of-Experts FFN with grouped local routing (TPU/pjit-friendly).

Tokens are reshaped to [G, Tg, D] routing groups with G sharded over the
batch mesh axes, so routing, capacity selection and dispatch are *local to a
shard* — no global sort, no all_to_all in the default path (the paper-era
lesson: keep the skewed traffic off the wire; cf. FN-Cache). Expert weights
are stacked [E, ...] and sharded over ('data' fsdp, 'model' tp) like dense
weights.

Dispatch is gather-based (not the [T, E, C] one-hot einsum, which is O(T*E*C)
memory): per expert, ``top_k`` selects up to C assigned tokens; gathered rows
are a dense [G, E, C, D] batch fed through batched expert matmuls, then
scatter-added back with router weights. FLOPs = 2*mats*topk*cf*T*D*F — the
standard capacity-factor MoE cost. Tokens overflowing an expert's capacity
are dropped (residual passes through), standard Switch behavior.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import actsharding
from repro.models.config import ModelConfig
from repro.models.layers import pdtype_of


def init_moe(cfg: ModelConfig, key: jax.Array):
    pd = pdtype_of(cfg)
    e, d, f = cfg.moe_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), pd) * d ** -0.5,
        "down": jax.random.normal(ks[1], (e, f, d), pd) * f ** -0.5,
        "up": jax.random.normal(ks[2], (e, d, f), pd) * d ** -0.5,
    }
    if cfg.mlp_act == "swiglu":
        p["gate"] = jax.random.normal(ks[3], (e, d, f), pd) * d ** -0.5
    return p


def _expert_ffn(cfg: ModelConfig, p, xe: jnp.ndarray) -> jnp.ndarray:
    """xe: [G, E, C, D] -> [G, E, C, D] through each expert's FFN."""
    dt = xe.dtype
    cw = actsharding.constrain_weight
    up = jnp.einsum("gecd,edf->gecf", xe,
                    cw(p["up"].astype(dt), (None, None, "model")))
    if cfg.mlp_act == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", xe,
                          cw(p["gate"].astype(dt), (None, None, "model")))
        h = jax.nn.silu(gate) * up
    elif cfg.mlp_act == "sq_relu":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("gecf,efd->gecd", h,
                      cw(p["down"].astype(dt), (None, "model", None)))


def moe_apply(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
              num_groups: int) -> jnp.ndarray:
    """x: [B, S, D]. ``num_groups`` must divide B*S and be a multiple of the
    batch-sharding factor so each group is shard-local."""
    b, s, d = x.shape
    t = b * s
    g = num_groups
    tg = t // g
    e, k = cfg.moe_experts, cfg.moe_top_k
    cap = max(1, int(tg * k / e * cfg.capacity_factor))
    cap = min(cap, tg)
    xg = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # [G,Tg,E]
    top_p, top_e = jax.lax.top_k(probs, k)                       # [G,Tg,k]
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)            # renorm

    # per-expert token selection: score[g, e, t] = router prob if assigned
    assign = jax.nn.one_hot(top_e, e, dtype=jnp.float32)         # [G,Tg,k,E]
    weight_te = jnp.einsum("gtke,gtk->gte", assign, top_p)       # [G,Tg,E]
    assigned = weight_te > 0
    score = jnp.where(assigned, weight_te, -1.0)
    sel_score, sel_idx = jax.lax.top_k(
        jnp.swapaxes(score, 1, 2), cap)                          # [G,E,C]
    sel_valid = sel_score > 0

    xe = jnp.take_along_axis(xg[:, None], sel_idx[..., None], axis=2)
    ye = _expert_ffn(cfg, p, xe)                                 # [G,E,C,D]
    wsel = jnp.take_along_axis(jnp.swapaxes(weight_te, 1, 2), sel_idx, axis=2)
    ye = ye * (wsel * sel_valid)[..., None].astype(ye.dtype)

    out = jnp.zeros_like(xg)
    flat_idx = sel_idx.reshape(g, e * cap)
    flat_y = ye.reshape(g, e * cap, d)
    out = jax.vmap(lambda o, i, y: o.at[i].add(y))(out, flat_idx, flat_y)
    return out.reshape(b, s, d)
