"""StreamingSGNSTrainer — train SGNS on FN-Multi round *k−1* while the walk
engine generates round *k* (DESIGN.md §14).

Stage-2's "host corpus cliff" (ROADMAP): the old launcher collected every
round into one ``np.concatenate``, expanded all (center, context) pairs in
numpy, and re-uploaded every batch per step. Here the corpus never exists
on host:

* each round's walks upload to device **once** (plus a [V]-sized alias
  refresh); pair generation is window-offset gathers over the resident
  walks array (``repro.train.pairs``);
* negatives are O(1) device alias draws from the incrementally maintained
  unigram^0.75 counts (rounds 0..k when training round k);
* each epoch over a round is ONE device program (``lax.scan`` over the
  fixed [steps, batch] permutation grid) — one compile per (walkers,
  length) round shape, one dispatch per epoch, params/opt_state buffers
  donated, so round k+1 never retraces and the host never sits in the
  step loop;
* the fused Pallas SGNS kernel rides behind ``sgns_backend="fused"``
  (``repro.core.skipgram.sgns_grads``).

Streamed and concat consumption are **bit-identical**: every batch depends
only on (round index, epoch, step index) and the cumulative corpus counts
up to that round — never on arrival timing — so training on a live
dispatch-ahead round iterator equals collecting all rounds first and
replaying them (tested in tests/test_train.py).
"""
from __future__ import annotations

import functools
import math
import time
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alias import build_alias
from repro.core.skipgram import (SGNSConfig, init_params, normalize_embeddings,
                                 sgns_grads)
from repro.optim.optimizers import adam, adam_rows, apply_updates
from repro.train.pairs import device_negatives, device_pairs, num_pairs
from repro.train.shard import (mesh_shards, pow2_bucket, shard_opt_state,
                               shard_params, train_epoch_sharded)
from repro.train.stats import TrainRecorder, TrainStats


@functools.partial(jax.jit, static_argnames=("window",))
def _gen_pairs(walks, window):
    """Resident-walks -> pair arrays + per-pair validity + valid count."""
    c, x, valid = device_pairs(walks, window)
    return c, x, valid, jnp.sum(valid)


@functools.partial(jax.jit, static_argnames=("n", "steps", "batch"))
def _perm_batches(key, n, steps, batch):
    """Device shuffle of ``n`` pair slots, padded to the fixed step grid and
    reshaped [steps, batch] (pad slots are masked by position in the step)."""
    perm = jax.random.permutation(key, n)
    return jnp.pad(perm, (0, steps * batch - n)).reshape(steps, batch)


@functools.partial(jax.jit,
                   static_argnames=("opt", "negatives", "backend", "n_pairs"),
                   donate_argnums=(0, 1))
def _train_epoch(params, opt_state, c, x, valid, perm2d, prob, alias, key,
                 *, opt, negatives, backend, n_pairs):
    """One epoch over one round as a single device program: lax.scan over
    the [steps, batch] permutation grid — per batch, a permutation-row
    gather + alias negatives + SGNS update. One dispatch per epoch (no
    per-step host round trips), one compile per round shape. Returns
    (params, opt_state, per-step losses [steps])."""
    batch_size = perm2d.shape[1]

    def body(carry, s):
        params, opt_state = carry
        idx = perm2d[s]
        in_bounds = (s * batch_size + jnp.arange(batch_size)) < n_pairs
        batch = {
            "center": c[idx],
            "pos": x[idx],
            "neg": device_negatives(jax.random.fold_in(key, s), prob, alias,
                                    (batch_size, negatives)),
            "valid": (valid[idx] & in_bounds).astype(jnp.float32),
        }
        loss, grads = sgns_grads(params, batch, backend)
        updates, opt_state = opt.update(grads, opt_state, params)
        return (apply_updates(params, updates), opt_state), loss

    (params, opt_state), losses = jax.lax.scan(
        body, (params, opt_state), jnp.arange(perm2d.shape[0]))
    return params, opt_state, losses


class StreamingSGNSTrainer:
    """Consume per-round walk arrays as they complete; keep all corpus work
    on device. One instance = one training run (params live across rounds).

    ``shard_tables=True`` range-partitions the embedding tables (and their
    Adam moments) across the 1-D ``rw`` mesh and runs each epoch under
    ``shard_map`` with sparse owner gathers + lazy row-Adam
    (``repro.train.shard``; DESIGN.md §16). The sharded run is bit-identical
    across shard counts for the same seeds; note it is a *different
    optimizer semantics* than the dense default (untouched rows keep their
    moments frozen), so compare sharded runs against ``shard_tables=True``
    on one device, not against the dense path.
    """

    def __init__(self, vocab: int, dim: int = 128, window: int = 10,
                 negatives: int = 5, batch_size: int = 1024,
                 lr: float = 0.025, epochs: int = 1, seed: int = 0,
                 sgns_backend: str = "jnp", power: float = 0.75,
                 record_loss: bool = True, shard_tables: bool = False,
                 mesh=None):
        self.vocab = vocab
        self.dim = dim
        self.window = window
        self.negatives = negatives
        self.batch_size = batch_size
        self.epochs = epochs
        self.seed = seed
        self.sgns_backend = sgns_backend
        self.power = power
        self.record_loss = record_loss
        self.shard_tables = bool(shard_tables)
        scfg = SGNSConfig(vocab=vocab, dim=dim, negatives=negatives)
        self.params = init_params(scfg, jax.random.PRNGKey(seed))
        if self.shard_tables:
            # mesh-partitioned tables + lazy row-Adam (repro.train.shard):
            # same init values, padded to the shard multiple, range-sharded
            from repro.launch.mesh import make_table_mesh
            from jax.sharding import Mesh
            self.mesh = mesh if isinstance(mesh, Mesh) and \
                tuple(mesh.axis_names) == ("rw",) else make_table_mesh(mesh)
            self.shards = mesh_shards(self.mesh)
            self.params = shard_params(self.params, vocab, self.mesh)
            self._opt = adam_rows(lr)
            self.opt_state = shard_opt_state(self.params, self.mesh)
            self._u_in = pow2_bucket(batch_size)
            self._u_out = pow2_bucket(batch_size * (1 + negatives))
        else:
            self.mesh = None
            self.shards = 1
            self._opt = adam(lr)
            self.opt_state = self._opt.init(self.params)
        self._counts = np.zeros(vocab, np.float64)
        self._key = jax.random.PRNGKey(seed)
        self._round = 0
        self._losses: list = []        # device scalars; fetched lazily
        self._pair_counts: list = []   # device scalars (valid pairs / round)
        self.recorder = TrainRecorder(sgns_backend, shards=self.shards)

    @classmethod
    def from_config(cls, vocab: int, cfg, **overrides
                    ) -> "StreamingSGNSTrainer":
        """Build from the SGNS half of a ``Node2VecConfig``-shaped object."""
        kw = dict(dim=cfg.dim, window=cfg.window, negatives=cfg.negatives,
                  batch_size=cfg.batch_size, lr=cfg.lr, epochs=cfg.epochs,
                  seed=cfg.seed,
                  sgns_backend=getattr(cfg, "sgns_backend", "jnp"))
        kw.update(overrides)
        return cls(vocab, **kw)

    # ---------------------------------------------------------- one round --
    def _alias_refresh(self, walks: np.ndarray):
        """Fold the round into the cumulative unigram counts and rebuild the
        [V] negative-sampling alias table (O(V) host, uploaded once)."""
        self._counts += np.bincount(walks.reshape(-1), minlength=self.vocab)
        freq = self._counts ** self.power
        if freq.sum() == 0:
            freq = np.ones(self.vocab)
        prob_np, alias_np = build_alias(freq)
        return jnp.asarray(prob_np), jnp.asarray(alias_np), \
            prob_np.nbytes + alias_np.nbytes

    def consume(self, walks: np.ndarray) -> None:
        """Train one epoch pass (``epochs`` sub-passes) over one round."""
        t0 = time.perf_counter()
        walks = np.ascontiguousarray(walks, np.int32)  # host-ok: round input
        w, l = walks.shape
        n_pairs = num_pairs(w, l, self.window)
        prob, alias, alias_bytes = self._alias_refresh(walks)
        if n_pairs == 0:
            self._round += 1
            self.recorder.round_trained(time.perf_counter() - t0, 0, 0,
                                        w * l, walks.nbytes + alias_bytes, 0)
            return
        dev_walks = jnp.asarray(walks)
        c, x, valid, n_valid = _gen_pairs(dev_walks, self.window)
        self._pair_counts.append(n_valid * self.epochs)
        steps = math.ceil(n_pairs / self.batch_size)
        rkey = jax.random.fold_in(self._key, self._round)
        for e in range(self.epochs):
            pkey, skey = jax.random.split(jax.random.fold_in(rkey, e))
            perm2d = _perm_batches(pkey, n_pairs, steps, self.batch_size)
            if self.shard_tables:
                self.params, self.opt_state, losses = train_epoch_sharded(
                    self.params, self.opt_state, c, x, valid, perm2d,
                    prob, alias, skey,
                    mesh=self.mesh, opt=self._opt,
                    negatives=self.negatives, backend=self.sgns_backend,
                    n_pairs=n_pairs, u_in=self._u_in, u_out=self._u_out)
            else:
                self.params, self.opt_state, losses = _train_epoch(
                    self.params, self.opt_state, c, x, valid, perm2d,
                    prob, alias, skey,
                    opt=self._opt, negatives=self.negatives,
                    backend=self.sgns_backend, n_pairs=n_pairs)
            if self.record_loss:
                self._losses.append(losses)
        self._round += 1
        # concat-equivalent H2D: the host path stages center/pos/neg (i32)
        # + valid (f32) per step — deterministic, so the ratio metric is exact
        per_step = 4 * self.batch_size * (3 + self.negatives)
        coll = 0
        if self.shard_tables:
            from repro.roofline.traffic import sgns_exchange_bytes
            coll = steps * self.epochs * sgns_exchange_bytes(
                self._u_in + self._u_out, self.dim, self.shards)
        self.recorder.round_trained(
            time.perf_counter() - t0, steps * self.epochs, 0, w * l,
            walks.nbytes + alias_bytes, steps * self.epochs * per_step,
            collective_bytes=coll)

    # ------------------------------------------------------------- driver --
    def train(self, source: Iterable[np.ndarray],
              max_rounds: Optional[int] = None
              ) -> Tuple[np.ndarray, TrainStats]:
        """Drive training over ``source`` (an iterator of per-round ``[W, L]``
        walk arrays — e.g. ``WalkRoundRunner.rounds()``, whose dispatch-ahead
        means round k+1 walks while this trainer optimizes round k).
        Returns (L2-normalized [V, dim] embeddings, :class:`TrainStats`).
        """
        t_start = time.perf_counter()
        it = iter(source)
        seen = 0
        while max_rounds is None or seen < max_rounds:
            t0 = time.perf_counter()
            try:
                walks = next(it)
            except StopIteration:
                break
            self.recorder.walk_waited(time.perf_counter() - t0)
            self.consume(np.asarray(walks))  # host-ok: per-round, not batch
            seen += 1
        emb, stats = self.finish(time.perf_counter() - t_start)
        return emb, stats

    def finish(self, wall_seconds: Optional[float] = None
               ) -> Tuple[np.ndarray, TrainStats]:
        """Flush the async step queue, fetch embeddings, freeze stats."""
        t0 = time.perf_counter()
        # terminal fetch ([:vocab] strips the shard-padding rows)
        emb = np.asarray(jax.device_get(            # host-ok: terminal fetch
            normalize_embeddings(self.params)))[:self.vocab]
        if self._pair_counts:
            self.recorder.pairs = int(sum(
                int(p) for p in jax.device_get(     # host-ok: terminal fetch
                    self._pair_counts)))
            self._pair_counts = [jnp.asarray(self.recorder.pairs)]
        self.recorder.finalized(time.perf_counter() - t0)
        if wall_seconds is None:   # direct consume() use, no train() driver
            wall_seconds = sum(self.recorder._waits) + self.recorder._train_s
        return emb, self.recorder.snapshot(wall_seconds)

    def loss_history(self) -> np.ndarray:
        """Per-step losses, concatenated over epochs/rounds (device sync)."""
        if not self._losses:
            return np.zeros(0, np.float32)
        return np.asarray(jax.device_get(           # host-ok: terminal fetch
            jnp.concatenate(self._losses)))


def train_streamed(g, cfg, mesh=None, checkpointer=None, **overrides
                   ) -> Tuple[np.ndarray, TrainStats]:
    """End-to-end streamed node2vec stage 2: walk rounds through a
    :class:`~repro.runtime.fault_tolerance.WalkRoundRunner` (dispatch-ahead,
    checkpointed) feeding a :class:`StreamingSGNSTrainer`. The streamed
    counterpart of ``repro.core.node2vec.node2vec``; same round seeds, so a
    concat replay of the same config reproduces it bit-for-bit.
    """
    from repro.runtime.fault_tolerance import WalkRoundRunner
    runner = WalkRoundRunner(g, cfg, mesh=mesh, checkpointer=checkpointer)
    if overrides.get("shard_tables") and "mesh" not in overrides:
        overrides["mesh"] = mesh   # table shards align with graph shards
    trainer = StreamingSGNSTrainer.from_config(g.n, cfg, **overrides)
    emb, stats = trainer.train(runner.rounds())
    return emb, stats
