"""Sharded SGNS: mesh-partitioned embedding tables, sparse-collective epochs
(DESIGN.md §16).

PR 8's streaming trainer keeps both [V, D] embedding tables (and their Adam
moments) on ONE device: trainable graph size is capped by one HBM, the §2
mesh idles through the train half of every streamed round, and the dense
Adam update touches all V·D entries per step. This module partitions
``emb_in``/``emb_out`` and their moments across the walk engine's 1-D ``rw``
mesh by **vertex range** — shard *s* owns rows ``[s·n_loc, (s+1)·n_loc)``,
the same ranges ``ShardedGraph`` gives graph shard *s* — and runs each
jitted ``lax.scan`` epoch under ``shard_map``:

* **replicated batch math** — pair gathers, negative alias draws, the
  unique-row dedup, the SGNS forward/backward (jnp closed form or the fused
  Pallas kernel), and the deduped gradient segment-sums run identically on
  every shard. Replication is what buys bit-identity across shard counts:
  every float reduction has an S-independent grouping, so the S-shard run
  equals the 1-shard run bit for bit (tested on 2 devices via subprocess).
* **sparse owner gather** — the per-batch unique row sets (bucketed to
  power-of-two sizes, the same anti-retrace trick as PR 9's update
  scatters) are fetched with one owner-masked psum per table: each shard
  contributes its owned rows, zeros elsewhere. ``x + 0.0`` is bitwise ``x``
  here (no ``-0.0`` can reach the table: params are never ``-0.0`` and
  masked lanes contribute ``+0.0``), so the gather is also S-independent.
* **owner-local lazy row-Adam** — gradients come back already deduped per
  unique row; each shard applies :func:`repro.optim.optimizers.adam_rows`
  to the rows it owns (`.at[].set(mode="drop")` on out-of-range redirects
  non-owned and fill rows) with per-shard donated moments. O(rows·D) table
  work per step instead of dense Adam's O(V·D) — that, not device
  parallelism, is where the pairs/sec win comes from on small hosts.

The epoch program's shapes depend only on (round shape, batch, caps), so
round k+1 never retraces; params/opt_state are donated through the jit.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.walk_distributed import RW_AXIS, _shard_map
from repro.kernels.sgns import sgns_row_grads
from repro.optim.optimizers import AdamState
from repro.train.pairs import device_negatives


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n: unique-row buffer caps snap to a small
    shape family so collective/scatter shapes never retrace when batch or
    negative counts vary across configs (cf. engine.update._pad_to_bucket).
    """
    return 1 << max(int(n) - 1, 0).bit_length()


def mesh_shards(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def table_rows(vocab: int, shards: int) -> int:
    """Padded global row count: vocab rounded up to a shard multiple, so the
    range partition ``owner(v) = v // (rows/shards)`` is exact (same layout
    rule as ``ShardedGraph``). Padding rows are zero and never touched."""
    return shards * math.ceil(vocab / max(shards, 1))


def table_sharding(mesh: Mesh) -> NamedSharding:
    """Row-range sharding for a [rows, D] table over the 1-D ``rw`` mesh."""
    return NamedSharding(mesh, P(RW_AXIS))


def shard_params(params, vocab: int, mesh: Mesh):
    """Pad the [V, D] tables to the mesh multiple and place them
    range-sharded. Identical values to the single-device tables on rows
    [:V]; the pad rows are zero."""
    vp = table_rows(vocab, mesh_shards(mesh))
    sh = table_sharding(mesh)

    def place(t):
        t = jnp.pad(t, ((0, vp - t.shape[0]), (0, 0)))
        return jax.device_put(t, sh)

    return jax.tree.map(place, params)


def shard_opt_state(params_sharded, mesh: Mesh) -> AdamState:
    """Adam moments in the exact layout of the tables; count replicated.
    Every leaf (count included) is committed to the mesh up front so round 0
    presents the same input shardings the epoch's own outputs have — an
    uncommitted count would cost one avoidable round-1 recompile."""
    sh = table_sharding(mesh)

    def zeros(p):
        return jax.device_put(jnp.zeros(p.shape, p.dtype), sh)

    count = jax.device_put(jnp.zeros((), jnp.int32),
                           NamedSharding(mesh, P()))
    return AdamState(count,
                     jax.tree.map(zeros, params_sharded),
                     jax.tree.map(zeros, params_sharded))


@functools.partial(jax.jit,
                   static_argnames=("mesh", "opt", "negatives", "backend",
                                    "n_pairs", "u_in", "u_out"),
                   donate_argnums=(0, 1))
def train_epoch_sharded(params, opt_state, c, x, valid, perm2d, prob, alias,
                        key, *, mesh, opt, negatives, backend, n_pairs,
                        u_in, u_out):
    """One epoch over one round, sharded: ``lax.scan`` over the [steps,
    batch] permutation grid under ``shard_map`` on ``mesh``. Same
    (round, epoch, step) keying as the dense ``_train_epoch`` — ``key`` is
    folded per step for negatives, ``perm2d`` rows pick the batch. Returns
    (params, opt_state, per-step losses [steps]); params/opt donated."""
    vp = params["emb_in"].shape[0]
    fill = jnp.int32(vp)         # unique-buffer pad id: out of every range
    batch_size = perm2d.shape[1]

    def epoch(params_loc, opt_loc, c, x, valid, perm2d, prob, alias, key):
        n_loc = params_loc["emb_in"].shape[0]
        row0 = jax.lax.axis_index(RW_AXIS) * n_loc

        def gather(tab, u):
            # owner-masked sparse gather: my rows or +0.0, psum routes them
            loc = u - row0
            safe = jnp.where((loc >= 0) & (loc < n_loc), loc, n_loc)
            rows = tab.at[safe].get(mode="fill", fill_value=0.0)
            return jax.lax.psum(rows, RW_AXIS)

        def owner_apply(tab, mu, nu, u, g_u, count):
            # lazy row-Adam on owned rows; non-owned/fill rows redirect to
            # the out-of-range index n_loc and are dropped (never negative:
            # jax wraps negative scatter indices even under mode="drop")
            loc = u - row0
            mine = (loc >= 0) & (loc < n_loc)
            li = jnp.where(mine, loc, n_loc)
            mu_r = mu.at[li].get(mode="fill", fill_value=0.0)
            nu_r = nu.at[li].get(mode="fill", fill_value=0.0)
            upd, mu_n, nu_n = opt.update(g_u, (mu_r, nu_r), count)
            p_n = tab.at[li].get(mode="fill", fill_value=0.0) + upd
            return (tab.at[li].set(p_n, mode="drop"),
                    mu.at[li].set(mu_n, mode="drop"),
                    nu.at[li].set(nu_n, mode="drop"))

        def body(carry, s):
            p, st = carry
            idx = perm2d[s]
            in_bounds = (s * batch_size + jnp.arange(batch_size)) < n_pairs
            center, pos = c[idx], x[idx]
            neg = device_negatives(jax.random.fold_in(key, s), prob, alias,
                                   (batch_size, negatives))
            v = (valid[idx] & in_bounds).astype(jnp.float32)

            # replicated dedup: sorted unique row sets + exact positions
            uc = jnp.unique(center, size=u_in, fill_value=fill)
            inv_c = jnp.searchsorted(uc, center).astype(jnp.int32)
            uo = jnp.unique(jnp.concatenate([pos, neg.reshape(-1)]),
                            size=u_out, fill_value=fill)
            inv_p = jnp.searchsorted(uo, pos).astype(jnp.int32)
            inv_n = jnp.searchsorted(uo, neg.reshape(-1)).astype(jnp.int32)

            rows_in = gather(p["emb_in"], uc)        # [u_in, D]
            rows_out = gather(p["emb_out"], uo)      # [u_out, D]
            ci = rows_in[inv_c]
            po = rows_out[inv_p]
            no = rows_out[inv_n].reshape(batch_size, negatives, -1)
            loss_sum, g_ci, g_po, g_no = sgns_row_grads(ci, po, no, v,
                                                        backend)
            denom = jnp.maximum(jnp.sum(v), 1.0)

            # deduped scatter-add onto the unique sets — replicated, in
            # batch order, so the reduction grouping is shard-independent
            g_uc = jnp.zeros_like(rows_in).at[inv_c].add(g_ci / denom)
            g_uo = (jnp.zeros_like(rows_out)
                    .at[inv_p].add(g_po / denom)
                    .at[inv_n].add(
                        g_no.reshape(batch_size * negatives, -1) / denom))

            count = st.count + 1
            emb_in, mu_in, nu_in = owner_apply(
                p["emb_in"], st.mu["emb_in"], st.nu["emb_in"], uc, g_uc,
                count)
            emb_out, mu_out, nu_out = owner_apply(
                p["emb_out"], st.mu["emb_out"], st.nu["emb_out"], uo, g_uo,
                count)
            new = ({"emb_in": emb_in, "emb_out": emb_out},
                   AdamState(count,
                             {"emb_in": mu_in, "emb_out": mu_out},
                             {"emb_in": nu_in, "emb_out": nu_out}))
            return new, loss_sum / denom

        (params_loc, opt_loc), losses = jax.lax.scan(
            body, (params_loc, opt_loc), jnp.arange(perm2d.shape[0]))
        return params_loc, opt_loc, losses

    state_spec = AdamState(P(), P(RW_AXIS), P(RW_AXIS))
    sharded = _shard_map(
        epoch, mesh,
        in_specs=(P(RW_AXIS), state_spec,
                  P(), P(), P(), P(), P(), P(), P()),
        out_specs=(P(RW_AXIS), state_spec, P()))
    return sharded(params, opt_state, c, x, valid, perm2d, prob, alias, key)
