"""TrainStats — the streaming trainer's structured diagnostics, mirroring
``repro.engine.plan.WalkStats`` and ``repro.serve.stats.ServeStats``
(DESIGN.md §14).

The walk engine reports what one *run* did and the serving layer what a
*traffic window* did; the trainer reports what one *streamed training run*
did: throughput (pairs/sec, tokens/sec), how much walk time hid behind
training (overlap efficiency), and how many bytes crossed the host→device
boundary versus what the per-batch host-staging path would have uploaded.

``TrainRecorder`` is the mutable accumulator the trainer feeds per round;
:meth:`TrainRecorder.snapshot` freezes it into a :class:`TrainStats`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TrainStats:
    """Frozen per-run streaming-training diagnostics.

    ``backend``            — SGNS gradient backend (``jnp`` | ``fused``).
    ``rounds`` / ``steps`` — FN-Multi rounds consumed / optimizer steps run.
    ``pairs``              — valid (center, context) pairs trained on
                             (self-pairs and batch padding are masked out and
                             not counted).
    ``tokens``             — corpus tokens consumed (walkers × length,
                             summed over rounds).
    ``walk_wait_seconds``  — host time blocked waiting on the walk source
                             (the *exposed* walk time; dispatched-ahead
                             rounds that finished behind training cost ~0).
    ``train_seconds``      — host time driving/finalizing training steps.
    ``wall_seconds``       — end-to-end duration of :meth:`~repro.train.
                             StreamingSGNSTrainer.train`.
    ``overlap_efficiency`` — estimated fraction of post-round-0 walk time
                             hidden behind training: round 0 is always fully
                             exposed (nothing to overlap with), so its wait
                             estimates the per-round walk cost c, and
                             efficiency = 1 − Σ wait[1:] / (c·(R−1)),
                             clipped to [0, 1]; 0.0 when R < 2. An estimate
                             (load noise moves c), reported for telemetry —
                             benches gate on the stream/concat wall-clock
                             ratio instead.
    ``pairs_per_sec`` / ``tokens_per_sec`` — throughput over wall time.
    ``h2d_bytes``          — actual host→device uploads: each round's walks
                             once, plus the per-round alias refresh.
    ``h2d_bytes_concat``   — what per-step host batch staging (the old
                             ``walks_to_sgns_batches`` path) would have
                             uploaded for the same steps: exact, so the
                             stream/concat H2D ratio is deterministic.
    ``shards``             — table shards (1 = dense single-device tables).
    ``collective_bytes``   — analytic per-device bytes the sparse row
                             gathers/updates moved across the mesh
                             (``roofline.traffic.sgns_exchange_bytes`` per
                             step; exact — the bucketed buffer shapes are
                             static). 0 when ``shards == 1``. Mirrors
                             ``WalkStats.collective_bytes``.
    ``exposed_collective_bytes`` — the part on the critical path. The
                             sparse gather is barrier-style inside each
                             step today, so exposed == total; the field
                             exists (mirroring ``WalkStats``) so a future
                             double-buffered exchange shows up as a drop.
    ``collective_overlap_efficiency`` — ``1 − exposed/total`` (0 when
                             nothing is on the wire).
    """
    backend: str
    rounds: int = 0
    steps: int = 0
    pairs: int = 0
    tokens: int = 0
    walk_wait_seconds: float = 0.0
    train_seconds: float = 0.0
    wall_seconds: float = 0.0
    overlap_efficiency: float = 0.0
    pairs_per_sec: float = 0.0
    tokens_per_sec: float = 0.0
    h2d_bytes: int = 0
    h2d_bytes_concat: int = 0
    shards: int = 1
    collective_bytes: int = 0
    exposed_collective_bytes: int = 0
    collective_overlap_efficiency: float = 0.0


class TrainRecorder:
    """Mutable accumulator behind :class:`TrainStats`."""

    def __init__(self, backend: str, shards: int = 1) -> None:
        self.backend = backend
        self.shards = shards
        self._waits: list[float] = []
        self._train_s = 0.0
        self.rounds = 0
        self.steps = 0
        self.pairs = 0
        self.tokens = 0
        self.h2d_bytes = 0
        self.h2d_bytes_concat = 0
        self.collective_bytes = 0
        self.exposed_collective_bytes = 0

    # ------------------------------------------------------------ events --
    def walk_waited(self, seconds: float) -> None:
        self._waits.append(seconds)

    def round_trained(self, seconds: float, steps: int, pairs: int,
                      tokens: int, h2d_bytes: int, h2d_bytes_concat: int,
                      collective_bytes: int = 0,
                      exposed_collective_bytes: int | None = None) -> None:
        self._train_s += seconds
        self.rounds += 1
        self.steps += steps
        self.pairs += pairs
        self.tokens += tokens
        self.h2d_bytes += h2d_bytes
        self.h2d_bytes_concat += h2d_bytes_concat
        self.collective_bytes += collective_bytes
        # barrier-style sparse gathers: exposed == total unless told better
        self.exposed_collective_bytes += (
            collective_bytes if exposed_collective_bytes is None
            else exposed_collective_bytes)

    def finalized(self, seconds: float) -> None:
        """Terminal block (flushing the async step queue + fetching params)
        counts as training time."""
        self._train_s += seconds

    # ---------------------------------------------------------- snapshot --
    def overlap_efficiency(self) -> float:
        if len(self._waits) < 2:
            return 0.0
        per_round = self._waits[0]
        if per_round <= 0.0:
            return 0.0
        exposed = sum(self._waits[1:])
        eff = 1.0 - exposed / (per_round * (len(self._waits) - 1))
        return min(max(eff, 0.0), 1.0)

    def snapshot(self, wall_seconds: float) -> TrainStats:
        wall = max(wall_seconds, 1e-12)
        return TrainStats(
            backend=self.backend,
            rounds=self.rounds,
            steps=self.steps,
            pairs=self.pairs,
            tokens=self.tokens,
            walk_wait_seconds=sum(self._waits),
            train_seconds=self._train_s,
            wall_seconds=wall_seconds,
            overlap_efficiency=self.overlap_efficiency(),
            pairs_per_sec=self.pairs / wall,
            tokens_per_sec=self.tokens / wall,
            h2d_bytes=self.h2d_bytes,
            h2d_bytes_concat=self.h2d_bytes_concat,
            shards=self.shards,
            collective_bytes=self.collective_bytes,
            exposed_collective_bytes=self.exposed_collective_bytes,
            collective_overlap_efficiency=(
                1.0 - self.exposed_collective_bytes / self.collective_bytes
                if self.collective_bytes else 0.0),
        )
