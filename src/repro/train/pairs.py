"""Device-side SGNS corpus ops (DESIGN.md §14).

The host path (``repro.data.corpus``) materializes every (center, context)
pair as numpy arrays — O(pairs) host memory and one H2D upload per batch.
Here the walks array stays resident on device and the corpus never exists:

* :func:`device_pairs` — window-offset gathers over the resident ``[W, L]``
  walks array. Emits the same pair stream, in the same order, as the host
  ``sgns_pairs`` *before* its ``c != x`` filter; self-pairs are returned as
  a validity mask instead of being compacted out, so every shape is static
  (one compile per (W, L, window), no per-round retrace).
* :func:`device_negatives` — O(1) Vose alias draws (the same two-uniform
  scheme as ``repro.core.alias.alias_sample``) from the unigram^0.75 table,
  vectorized over the whole ``[B, K]`` block.

Both are pure jnp and meant to be called *inside* a jit (the streaming
trainer fuses pair gather + negative draw + train step into one program).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def num_pairs(walkers: int, length: int, window: int) -> int:
    """Static pair count for a [walkers, length] round: for each offset
    ``off in 1..min(window, length-1)`` there are ``2 * walkers *
    (length - off)`` ordered pairs (both directions)."""
    o = min(window, length - 1)
    return 2 * walkers * (o * length - o * (o + 1) // 2)


def device_pairs(walks: jnp.ndarray, window: int):
    """All (center, context) pairs within ±window along each walk.

    walks: [W, L] int32 on device. Returns ``(centers, contexts, valid)``,
    each ``[num_pairs(W, L, window)]``; ``valid`` masks self-pairs
    (``center == context`` — dead-end self-loop tails), which the host path
    filters out and this path trains through with zero weight.
    """
    w, l = walks.shape
    centers, contexts = [], []
    for off in range(1, min(window, l - 1) + 1):
        a = walks[:, :l - off].reshape(-1)
        b = walks[:, off:].reshape(-1)
        centers.append(a)
        contexts.append(b)
        centers.append(b)
        contexts.append(a)
    if not centers:
        z = jnp.zeros(0, jnp.int32)
        return z, z, jnp.zeros(0, bool)
    c = jnp.concatenate(centers)
    x = jnp.concatenate(contexts)
    return c, x, c != x


def device_negatives(key: jax.Array, prob: jnp.ndarray, alias: jnp.ndarray,
                     shape) -> jnp.ndarray:
    """Draw ``shape`` negatives from the alias table ``(prob [V], alias [V])``
    in one vectorized O(1)-per-draw pass."""
    vocab = prob.shape[0]
    k1, k2 = jax.random.split(key)
    slots = jax.random.randint(k1, shape, 0, vocab)
    u = jax.random.uniform(k2, shape)
    return jnp.where(u >= prob[slots], alias[slots], slots).astype(jnp.int32)
