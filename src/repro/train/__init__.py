"""repro.train — streamed on-device walk→SGNS training (DESIGN.md §14).

    from repro.train import StreamingSGNSTrainer, train_streamed

    trainer = StreamingSGNSTrainer(vocab=g.n, dim=64, window=10)
    emb, stats = trainer.train(runner.rounds())   # trains k-1 while k walks
"""
from repro.train.pairs import device_negatives, device_pairs, num_pairs
from repro.train.shard import (pow2_bucket, shard_opt_state, shard_params,
                               table_rows, train_epoch_sharded)
from repro.train.stats import TrainRecorder, TrainStats
from repro.train.stream import StreamingSGNSTrainer, train_streamed

__all__ = [
    "StreamingSGNSTrainer", "TrainRecorder", "TrainStats",
    "device_negatives", "device_pairs", "num_pairs", "pow2_bucket",
    "shard_opt_state", "shard_params", "table_rows", "train_epoch_sharded",
    "train_streamed",
]
