"""Graph-embedding serving driver: the `repro.serve` counterpart of
``launch/serve.py`` (which serves the LM side).

Builds the full story end to end: dataset spec -> walks -> SGNS embeddings
-> resident :class:`~repro.serve.EmbeddingService` -> synthetic Zipf traffic
replayed against the real clock -> a ``ServeStats`` report (p50/p99 latency,
QPS, cache hit rate, batch occupancy).

  PYTHONPATH=src python -m repro.launch.serve_graph --smoke
  PYTHONPATH=src python -m repro.launch.serve_graph \
      --graph "rmat:k=14,deg=16,relabel=degree" --requests 20000 --alpha 1.2
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.node2vec import Node2VecConfig
from repro.data import open_graph
from repro.engine import WalkPlan
from repro.serve import EmbeddingService, synthetic_trace


def build_service(args) -> EmbeddingService:
    store = open_graph(args.graph, cache_dir=args.graph_cache)
    g = store.graph
    print(f"graph: {args.graph} -> n={g.n} m={g.m} maxdeg={g.max_degree}")
    cfg = Node2VecConfig(walk_length=args.walk_length, num_walks=args.rounds,
                         dim=args.dim, epochs=1, batch_size=4096,
                         cap=args.cap, seed=args.seed)
    t0 = time.time()
    svc = EmbeddingService.from_node2vec(
        store, cfg, plan=WalkPlan(backend="reference", cap=args.cap),
        cache_size=args.cache_size, linger_s=args.linger_ms * 1e-3,
        margin_s=args.margin_ms * 1e-3, walk_seed=args.seed)
    print(f"walk+SGNS+residency build: {time.time() - t0:.1f}s "
          f"(dim={args.dim}, cache={args.cache_size})")
    return svc


def replay(svc: EmbeddingService, args) -> None:
    trace = synthetic_trace(svc.graph.n, args.requests, alpha=args.alpha,
                            rank_share=args.rank_share, qps=args.qps,
                            deadline_s=args.deadline_ms * 1e-3,
                            seed=args.seed)
    # warm every bucket's jit cache so the report measures serving, not
    # compilation (and expiries mean real starvation, not compile stalls)
    for b in svc.batcher.buckets:
        nodes = [0] * b
        svc.embed(nodes, window=0)
        if args.window:
            svc.embed(nodes, window=args.window)
        svc.rank_neighbors(nodes, args.k)
    t0 = time.time()
    for ev in trace:
        svc.submit(ev.kind, ev.node, window=args.window, k=args.k,
                   deadline_s=ev.deadline_s)
        svc.pump()
    svc.drain()
    wall = time.time() - t0
    st = svc.stats()
    print(f"\ntrace: {args.requests} reqs, zipf a={args.alpha}, "
          f"rank share {args.rank_share:.0%}, deadline "
          f"{args.deadline_ms:.0f}ms, wall {wall:.2f}s")
    print(f"{'metric':<22}{'value':>14}")
    for name, val in [
        ("requests", f"{st.requests}"),
        ("expired", f"{st.expired}"),
        ("batches", f"{st.batches}"),
        ("p50 latency (us)", f"{st.p50_latency_us:.0f}"),
        ("p99 latency (us)", f"{st.p99_latency_us:.0f}"),
        ("QPS", f"{st.qps:.0f}"),
        ("cache hit rate", f"{st.cache_hit_rate:.3f}"),
        ("batch occupancy", f"{st.batch_occupancy:.3f}"),
    ]:
        print(f"{name:<22}{val:>14}")
    if st.requests + st.expired < args.requests:
        raise SystemExit("lost responses: "
                         f"{st.requests + st.expired} < {args.requests}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="small graph + short trace (default)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--graph", default=None,
                    help="dataset spec (repro.data.ingest registry)")
    ap.add_argument("--graph-cache", default=None)
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--cap", type=int, default=32,
                    help="FN-Cache cold row width (hot set = deg > cap)")
    ap.add_argument("--walk-length", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--alpha", type=float, default=1.2,
                    help="Zipf exponent of query popularity")
    ap.add_argument("--rank-share", type=float, default=0.5)
    ap.add_argument("--qps", type=float, default=20_000.0,
                    help="trace arrival rate (closed-loop replay)")
    ap.add_argument("--deadline-ms", type=float, default=50.0)
    ap.add_argument("--window", type=int, default=0,
                    help="walk-averaged embed context window (0 = gather)")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--cache-size", type=int, default=512)
    ap.add_argument("--linger-ms", type=float, default=0.2)
    ap.add_argument("--margin-ms", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.graph is None:
        args.graph = ("skew:s=4,k=9,deg=20,seed=3,relabel=degree"
                      if args.smoke else
                      "rmat:k=16,deg=16,seed=0,relabel=degree")
    if args.dim is None:
        args.dim = 64 if args.smoke else 128
    if args.requests is None:
        args.requests = 2000 if args.smoke else 50_000

    svc = build_service(args)
    replay(svc, args)


if __name__ == "__main__":
    main()
