import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's own computation: the distributed Fast-Node2Vec
superstep on the production 512-chip mesh, at WeC-26 scale (2^26 vertices,
avg degree ~100, max degree ~2.8k — paper Table 1), WITHOUT building the
graph: every array is a ShapeDtypeStruct, fed to the unified engine as an
abstract ShardedGraph and measured via ``WalkEngine.analyze()``.

Cells (the paper's algorithm progression, §3.4):
  fn_base    cap = max_degree, no hot set        (paper FN-Base)
  fn_cache   cap = 128, hot tail replicated      (paper FN-Cache)
  fn_approx  fn_cache + O(1) alias at hot v      (paper FN-Approx)
plus beyond-paper variants used by the §Perf hillclimb (bf16 exchange
payload, visit-aware request capacity) — see EXPERIMENTS.md §Perf.

The collective term here is the NEIG-message volume the paper's Figs. 4/14
measure — on TPU it is the all_to_all operand bytes, read directly from the
lowered HLO.

  PYTHONPATH=src python -m repro.launch.dryrun_walk [--cell fn_base]
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.core.walk_distributed import ShardedGraph
from repro.engine import WalkEngine, WalkPlan
from repro.launch.mesh import make_rw_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun_walk")

# WeC-26 scale (paper Table 1: |V|=2^26, avg deg 100, max deg 2771)
N = 1 << 26
MAX_DEG = 2816          # max degree rounded up to a lane multiple
SHARDS = 512
ROUNDS = 8              # FN-Multi: walkers per round = N / ROUNDS
W_LOCAL = N // ROUNDS // SHARDS
HOT_K = 1 << 15         # replicated hot rows (32k x hot_cap x 8B ~ 0.7GB)


def abstract_graph(cap: int, hot_cap: int, dtype_w=jnp.float32
                   ) -> ShardedGraph:
    n_pad = N

    def sds(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    return ShardedGraph(
        n=n_pad, n_orig=N, num_shards=SHARDS, cap=cap, hot_cap=hot_cap,
        adj=sds((n_pad, cap), jnp.int32), wgt=sds((n_pad, cap), dtype_w),
        alias_p=sds((n_pad, cap), jnp.float32),
        alias_i=sds((n_pad, cap), jnp.int32),
        deg=sds((n_pad,), jnp.int32),
        hot_ids=sds((HOT_K,), jnp.int32),
        hot_adj=sds((HOT_K, hot_cap), jnp.int32),
        hot_wgt=sds((HOT_K, hot_cap), dtype_w),
        hot_alias_p=sds((HOT_K, hot_cap), jnp.float32),
        hot_alias_i=sds((HOT_K, hot_cap), jnp.int32),
        hot_deg=sds((HOT_K,), jnp.int32),
        hot_wmin=sds((HOT_K,), jnp.float32),
        hot_wmax=sds((HOT_K,), jnp.float32))


CELLS = {
    # name: (cap, hot_cap, mode, capacity_per_dest)
    # fn_base: every row at max-degree width, no cache; capacity sized for
    # ALL walkers being remote cold (cf=4 over uniform destinations).
    "fn_base": (MAX_DEG, MAX_DEG, "exact", 4 * W_LOCAL // SHARDS),
    # fn_cache: cold rows capped at 128 (hot tail replicated) -> exchange
    # payload width drops 22x; same request capacity.
    "fn_cache": (128, MAX_DEG, "exact", 4 * W_LOCAL // SHARDS),
    # fn_approx: hot vertices sampled O(1) from replicated alias tables.
    "fn_approx": (128, MAX_DEG, "approx", 4 * W_LOCAL // SHARDS),
    # beyond-paper: hot vertices ALWAYS take the O(1) alias path, which lets
    # the exact pass run at cold width only — the static-shape-native form
    # of FN-Approx (plain FN-Approx computes BOTH branches under `where`,
    # so its compute saving never materializes in SPMD; measured).
    "fn_approx_always": (128, MAX_DEG, "approx_always",
                         4 * W_LOCAL // SHARDS),
    # beyond-paper: popular vertices never enter the exchange AND the
    # measured hot-visit share (bench_skew: ~0.5+ on skewed graphs) means
    # cold requests are ~half of walkers -> capacity cf 4 -> 2.
    "fn_approx_visitcap": (128, MAX_DEG, "approx_always",
                           2 * W_LOCAL // SHARDS),
    # beyond-paper: bf16 edge weights in the exchange payload (ids stay i32).
    # NOTE: the CPU backend upcasts bf16 collectives to f32 (isolated and
    # verified), so this win is invisible in CPU-lowered HLO; on TPU the
    # payload drops 8B -> 6B per edge slot (0.75x).
    "fn_approx_bf16": (128, MAX_DEG, "approx_always",
                       2 * W_LOCAL // SHARDS),
}


def run_cell(name: str, length: int = 4, save: bool = True):
    cap, hot_cap, mode, capacity = CELLS[name]
    dtype_w = jnp.bfloat16 if name.endswith("bf16") else jnp.float32
    mesh = make_rw_mesh()
    g = abstract_graph(cap, hot_cap, dtype_w)
    plan = WalkPlan(p=0.5, q=2.0, length=length, mode=mode, approx_eps=1e-3,
                    backend="sharded", capacity=capacity)
    engine = WalkEngine.build(g, plan, mesh=mesh)
    art = engine.analyze(num_walkers=W_LOCAL * SHARDS)
    art["cell"] = name
    art["bottleneck"] = ("collective" if art["t_collective"] >
                         art["t_compute"] else "compute")
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(os.path.join(ART_DIR, f"{name}.json"), "w") as f:
            json.dump(art, f, indent=1)
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    args = ap.parse_args()
    cells = [args.cell] if args.cell else list(CELLS)
    print(f"{'cell':22s} {'t_compute':>10s} {'t_collective':>12s} "
          f"{'coll GiB/step':>13s} {'dominant':>10s}")
    for c in cells:
        a = run_cell(c)
        print(f"{c:22s} {a['t_compute']:10.3e} {a['t_collective']:12.3e} "
              f"{a['coll_bytes_per_step_per_dev']/2**30:13.3f} "
              f"{a['bottleneck']:>10s}", flush=True)


if __name__ == "__main__":
    main()
