"""End-to-end training launcher.

Two modes, selectable via ``--task``:

* ``node2vec``  — the paper's pipeline: RMAT graph -> distributed
  Fast-Node2Vec walks (FN-Multi rounds, checkpointed) -> SGNS embeddings.
  Stage 2 streams: the trainer optimizes round k-1 on device (resident
  walks, device pair-gen + alias negatives, ``--sgns-backend`` jnp/fused)
  while round k walks; ``--concat`` selects the generate-then-train
  host-corpus baseline.
* ``lm``        — train any assigned architecture (``--arch``) on the walk
  corpus (DeepWalk-style token streams) or on synthetic tokens, with the
  production sharding rules, checkpoint/restart, and (optionally) int8
  error-feedback gradient compression across data-parallel replicas.

This launcher is sized to run REAL steps on whatever devices exist (CPU here,
TPU pod in production); the dry-run path (launch/dryrun.py) covers the
production mesh shapes.

Examples:
  PYTHONPATH=src python -m repro.launch.train --task node2vec --k 10 --rounds 2
  PYTHONPATH=src python -m repro.launch.train --task lm --arch yi-6b --smoke \
      --steps 20
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.core.node2vec import Node2VecConfig, train_embeddings
from repro.data import open_graph
from repro.data.corpus import walks_to_lm_tokens
from repro.engine import WalkEngine, WalkPlan
from repro.launch.mesh import make_rw_mesh
from repro.models import model as M
from repro.optim.optimizers import adamw, apply_updates
from repro.optim.grad_utils import clip_by_global_norm
from repro.runtime.fault_tolerance import WalkRoundRunner
from repro.train import StreamingSGNSTrainer


def graph_spec(args) -> str:
    """``--graph`` wins; otherwise the legacy --k/--avg-degree WeC knobs."""
    return args.graph or f"wec:k={args.k},deg={args.avg_degree:g}," \
                         f"seed={args.seed}"


def run_node2vec(args):
    g = open_graph(graph_spec(args), cache_dir=args.graph_cache).graph
    print(f"graph: {graph_spec(args)} -> n={g.n} m={g.m} "
          f"maxdeg={g.max_degree}")
    mesh = make_rw_mesh() if jax.device_count() > 1 else None
    n2v = Node2VecConfig(p=args.p, q=args.q, walk_length=args.walk_length,
                         num_walks=args.rounds, dim=args.dim,
                         window=args.window, negatives=args.negatives,
                         batch_size=args.sgns_batch,
                         sgns_backend=args.sgns_backend,
                         mode=args.mode, cap=args.cap, seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir)
    runner = WalkRoundRunner(g, n2v, mesh=mesh, checkpointer=ckpt)

    if args.concat:
        # generate-then-train baseline (the pre-streaming pipeline shape):
        # collect every round on host, then run the host corpus path
        walks = np.concatenate(list(runner.rounds()), axis=0)
        print(f"corpus: {walks.shape[0]} walks of {walks.shape[1]} steps")
        emb = train_embeddings(g, walks, n2v)
    else:
        # streamed stage 2: runner.rounds() dispatches round k+1 before
        # yielding round k, so the trainer optimizes k while k+1 walks —
        # the corpus never materializes on host
        trainer = StreamingSGNSTrainer.from_config(
            g.n, n2v, shard_tables=args.shard_tables, mesh=mesh)
        emb, ts = trainer.train(runner.rounds())
        print(f"train[{ts.backend}]: {ts.rounds} rounds, {ts.steps} steps, "
              f"{ts.pairs} pairs in {ts.wall_seconds:.1f}s "
              f"({ts.pairs_per_sec:.0f} pairs/s, "
              f"{ts.tokens_per_sec:.0f} tokens/s)")
        print(f"overlap: walk_wait {ts.walk_wait_seconds:.2f}s, "
              f"efficiency {ts.overlap_efficiency:.2f}; "
              f"h2d {ts.h2d_bytes} B vs {ts.h2d_bytes_concat} B staged")
        if ts.shards > 1:
            print(f"shards: {ts.shards} table shards, "
                  f"collective {ts.collective_bytes} B "
                  f"({ts.exposed_collective_bytes} B exposed)")
    out = os.path.join(args.ckpt_dir, "embeddings.npy")
    np.save(out, emb)
    print(f"embeddings: {emb.shape} -> {out}")


def run_lm(args):
    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    opt = adamw(lr=args.lr)
    opt_state = opt.init(params)
    ckpt = Checkpointer(args.ckpt_dir)
    start_step = 0
    if ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore((params, opt_state))
        start_step = meta["step"]
        print(f"resumed from step {start_step}")

    # corpus: walks over a small graph -> token sequences
    g = open_graph(args.graph, cache_dir=args.graph_cache).graph \
        if args.graph \
        else open_graph(f"wec:k={max(args.k, 8)},deg=10,seed={args.seed}").graph
    walks = WalkEngine.build(
        g, WalkPlan(p=1.0, q=1.0, length=64)).run(seed=args.seed).walks
    seq = args.seq
    tokens = walks_to_lm_tokens(walks % cfg.vocab, seq + 1)
    print(f"corpus: {tokens.shape[0]} sequences of {seq + 1} tokens")

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, gnorm

    bsz = args.batch
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for step in range(start_step, args.steps):
        idx = rng.integers(0, tokens.shape[0], size=bsz)
        seqs = tokens[idx]
        batch = {"tokens": jnp.asarray(seqs[:, :-1]),
                 "labels": jnp.asarray(seqs[:, 1:])}
        if cfg.enc_layers:
            batch["frames"] = jnp.zeros(
                (bsz, cfg.num_audio_frames, cfg.d_model), jnp.float32)
        if cfg.cross_every and not cfg.enc_layers:
            batch["patches"] = jnp.zeros(
                (bsz, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        params, opt_state, loss, gnorm = train_step(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} ({dt:.1f}s)")
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            ckpt.save(step, (params, opt_state), blocking=False)
    ckpt.save(args.steps, (params, opt_state))
    print("done; final loss", float(loss))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["node2vec", "lm"], default="node2vec")
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--graph", default=None,
                    help="dataset spec (repro.data.open_graph): "
                         "'wec:k=12,deg=30', 'edgelist:/path/edges.txt', "
                         "'csr:/path/cache_dir', ... (overrides --k)")
    ap.add_argument("--graph-cache", default=None,
                    help="CSR cache dir for edgelist specs (build once, "
                         "memmap thereafter)")
    ap.add_argument("--k", type=int, default=10, help="RMAT log2 vertices")
    ap.add_argument("--avg-degree", type=float, default=20)
    ap.add_argument("--p", type=float, default=1.0)
    ap.add_argument("--q", type=float, default=1.0)
    ap.add_argument("--walk-length", type=int, default=80)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--mode", choices=["exact", "approx"], default="exact")
    ap.add_argument("--cap", type=int, default=None)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--window", type=int, default=10)
    ap.add_argument("--negatives", type=int, default=5)
    ap.add_argument("--sgns-batch", type=int, default=1024,
                    help="SGNS batch size (fixed-shape device batches)")
    ap.add_argument("--sgns-backend", choices=["jnp", "fused"],
                    default="jnp",
                    help="stage-2 gradient backend: jnp autodiff or the "
                         "fused Pallas SGNS kernel (interpret off-TPU)")
    ap.add_argument("--concat", action="store_true",
                    help="generate-then-train baseline instead of the "
                         "streamed on-device trainer")
    ap.add_argument("--shard-tables", action="store_true",
                    help="range-partition the SGNS tables + Adam moments "
                         "over the rw mesh (sparse-collective sharded "
                         "training; DESIGN.md §16). Bit-identical across "
                         "shard counts; needs >1 device to actually shard")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    if args.task == "node2vec":
        run_node2vec(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
