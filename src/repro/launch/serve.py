"""Batched serving driver: prefill + decode loop for any assigned arch.

Serves continuous batches of requests against a smoke-sized (CPU) or full
(TPU) model: prompts are prefilled (filling KV/SSM caches), then decoded
token-by-token with greedy or temperature sampling. Demonstrates the
sub-quadratic decode paths (mamba2 / jamba states, mixtral SWA ring buffer).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    b, s = args.batch, args.prompt_len
    max_len = s + args.gen

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)}
    if cfg.enc_layers:
        batch["frames"] = jnp.zeros((b, cfg.num_audio_frames, cfg.d_model),
                                    jnp.float32)
    if cfg.cross_every and not cfg.enc_layers:
        batch["patches"] = jnp.zeros((b, cfg.num_image_tokens, cfg.d_model),
                                     jnp.float32)

    prefill = jax.jit(lambda p, bb: M.prefill(cfg, p, bb, max_len=max_len))
    decode = jax.jit(lambda p, t, pos, c: M.serve_step(cfg, p, t, pos, c))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            k, logits / args.temperature).astype(jnp.int32)

    tok = sample(logits, key)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, caches = decode(params, tok,
                                jnp.asarray(s + i, jnp.int32), caches)
        tok = sample(logits, sub)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={s} gen={gen.shape[1]}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/max(args.gen-1,1)*1e3:.2f} ms/token")
    print("sample output ids:", gen[0][:12])


if __name__ == "__main__":
    main()
