"""Mesh construction (functions, never module-level constants — importing
this module must not touch jax device state)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Production mesh: 16x16 = 256 chips/pod; multi-pod adds a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over however many (possibly fake) devices tests have."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_rw_mesh(mesh: Mesh | None = None) -> Mesh:
    """1-D mesh over all devices for the walk engine's flattened ``rw`` axis
    (walks are data-parallel over every chip of the production mesh)."""
    devices = (np.asarray(mesh.devices).reshape(-1) if mesh is not None
               else np.asarray(jax.devices()))
    return Mesh(devices, ("rw",))
