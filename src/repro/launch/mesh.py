"""Mesh construction (functions, never module-level constants — importing
this module must not touch jax device state)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Production mesh: 16x16 = 256 chips/pod; multi-pod adds a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over however many (possibly fake) devices tests have."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_rw_mesh(mesh: Mesh | None = None) -> Mesh:
    """1-D mesh over all devices for the walk engine's flattened ``rw`` axis
    (walks are data-parallel over every chip of the production mesh)."""
    devices = (np.asarray(mesh.devices).reshape(-1) if mesh is not None
               else np.asarray(jax.devices()))
    return Mesh(devices, ("rw",))


def make_table_mesh(mesh: Mesh | None = None,
                    max_shards: int | None = None) -> Mesh:
    """1-D ``rw`` mesh for vertex-range-sharded SGNS tables (DESIGN.md §16).

    Same axis name and device order as :func:`make_rw_mesh`, so table shard
    *s* owns the same vertex range as the walk engine's graph shard *s* —
    after ``relabel=degree`` the hot vertices are spread across table shards
    the same deliberate way they are spread across graph shards.
    ``max_shards`` restricts to a device prefix (benches compare shard
    counts inside one multi-device process this way).
    """
    devices = (np.asarray(mesh.devices).reshape(-1) if mesh is not None
               else np.asarray(jax.devices()))
    if max_shards is not None:
        devices = devices[:max_shards]
    return Mesh(devices, ("rw",))
