import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, compiles,
fits, and report its roofline terms — without TPU hardware.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder devices. (Smoke tests
and benches must NOT import this module — they see 1 device.)

Per cell this driver does two compiles:
  1. full-depth **scanned** model: lower + compile on the production mesh —
     proves the sharding is coherent (no mismatch, no unsupported collective)
     and yields ``memory_analysis()`` (true per-device footprint).
  2. 1- and 2-superblock **unrolled** variants: ``cost_analysis()`` +
     HLO-text collective bytes, linearly extrapolated to full depth
     (cost_analysis does not multiply through ``while`` loops — verified).

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json, consumed by
EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline_table.py.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.optimizers import adamw, apply_updates
from repro.optim.grad_utils import clip_by_global_norm
from repro.roofline import analysis as roof
from repro.roofline import traffic

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _art_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ART_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


# ---------------- step functions ----------------

def make_train_fn(cfg: ModelConfig, num_groups: int):
    opt = adamw(lr=3e-4)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch, num_groups))(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    return opt, train_step


def lower_cell(cfg: ModelConfig, kind: str, seq: int, batch: int, mesh,
               num_groups: int):
    """Lower + compile one cell on ``mesh``; returns (compiled, lowered, s)."""
    from repro.models import transformer as tf
    from repro.models.actsharding import set_act_mesh

    set_act_mesh(mesh)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: M.init_params(cfg, key))
    pspecs = shd.param_specs(params_shape, mesh, cfg)
    p_shard = shd.to_named(pspecs, mesh)

    ispec = configs.input_specs(cfg, _shape_for(kind), batch=batch, seq=seq)
    bspecs = shd.batch_specs(ispec["batch"], mesh)
    b_shard = shd.to_named(bspecs, mesh)

    if kind == "train":
        opt, train_step = make_train_fn(cfg, num_groups)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_shard = _opt_shardings(opt_shape, pspecs, mesh)
        fn = jax.jit(train_step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_shape, opt_shape, ispec["batch"])
    elif kind == "prefill":
        def prefill_fn(params, batch):
            return M.prefill(cfg, params, batch, max_len=seq,
                             num_groups=num_groups)

        caches_shape = jax.eval_shape(
            lambda: tf.init_caches(cfg, batch, seq, jnp.dtype(cfg.dtype)))
        cspecs = shd.cache_specs(caches_shape, mesh, cfg)
        fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(
                         jax.sharding.NamedSharding(
                             mesh, shd.logits_spec(cfg, mesh, batch)),
                         shd.to_named(cspecs, mesh)))
        lowered = fn.lower(params_shape, ispec["batch"])
    else:  # decode — serve-mode params: bf16, TP-only (no per-token FSDP
        # all-gathers; inference keeps no optimizer state, so replicating
        # over `data` costs only params/TP bytes — fits every arch in bf16)
        from repro.models.actsharding import set_weight_constrain
        set_weight_constrain(False)
        cfg_srv = dataclasses.replace(cfg, param_dtype="bfloat16")
        params_shape = jax.eval_shape(
            lambda: M.init_params(cfg_srv, key))
        p_shard = shd.to_named(
            shd.param_specs(params_shape, mesh, cfg_srv, serve_mode=True),
            mesh)
        caches_shape = jax.eval_shape(
            lambda: tf.init_caches(cfg, batch, seq, jnp.dtype(cfg.dtype)))
        cspecs = shd.cache_specs(caches_shape, mesh, cfg)
        c_shard = shd.to_named(cspecs, mesh)

        def serve_fn(params, token, pos, caches):
            return M.serve_step(cfg_srv, params, token, pos, caches,
                                num_groups=num_groups)

        fn = jax.jit(
            serve_fn,
            in_shardings=(p_shard, b_shard["token"], b_shard["pos"],
                          c_shard),
            out_shardings=(jax.sharding.NamedSharding(
                mesh, shd.logits_spec(cfg, mesh, batch)), c_shard),
            donate_argnums=(3,))
        lowered = fn.lower(params_shape, ispec["batch"]["token"],
                           ispec["batch"]["pos"], caches_shape)
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, lowered, time.time() - t0


def _shape_for(kind: str) -> str:
    return {"train": "train_4k", "prefill": "prefill_32k",
            "decode": "decode_32k"}[kind]


def _opt_shardings(opt_shape, pspecs, mesh):
    """Optimizer state shares param specs; scalars replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def match(o):
        # AdamState(count, mu, nu): mu/nu mirror params
        return type(o)(NamedSharding(mesh, P()),
                       shd.to_named(pspecs, mesh),
                       shd.to_named(pspecs, mesh))

    return match(opt_shape)


# ---------------- per-cell analysis ----------------

def _cost_dict(compiled) -> Dict[str, float]:
    ca = roof.cost_dict(compiled.cost_analysis())
    return {"flops": float(ca.get("flops", 0.0) or 0.0),
            "bytes": float(ca.get("bytes accessed", 0.0) or 0.0)}


def run_cell(arch: str, shape: str, multi_pod: bool,
             save: bool = True, tag: str = "",
             cfg_override=None, mesh_shape=None) -> Dict[str, Any]:
    """``mesh_shape=(data, model)`` remaps the SAME 256 chips/pod to a
    different logical (data, model) split — the TP-degree tuning knob used in
    §Perf (small models want wide data axes, not 16-way TP)."""
    if mesh_shape is not None:
        d, m = mesh_shape
        if multi_pod:
            mesh = jax.make_mesh((2, d, m), ("pod", "data", "model"))
            mesh_name = f"pod2x{d}x{m}"
        else:
            mesh = jax.make_mesh((d, m), ("data", "model"))
            mesh_name = f"pod{d}x{m}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(mesh.devices.size)
    cfg = cfg_override or configs.get_config(arch)
    ok, why = configs.applicable(cfg, shape)
    if not ok:
        art = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        if save:
            with open(_art_path(arch, shape, mesh_name, tag), "w") as f:
                json.dump(art, f, indent=1)
        return art

    info = configs.SHAPES[shape]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    num_groups = shd.axis_size(mesh, shd.batch_axes(mesh))
    if batch % num_groups != 0:
        num_groups = 1

    t_all = time.time()
    # 1) full-depth scanned compile: shardability + memory
    compiled, lowered, t_compile = lower_cell(cfg, kind, seq, batch, mesh,
                                              num_groups)
    mem = compiled.memory_analysis()
    # per-device (verified): arguments = params+opt+batch shard; temp on the
    # CPU backend is a no-liveness upper bound (sum of all HLO values) — we
    # record both and treat argument+output as the residency floor.
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes_upper": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "resident_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
        + (getattr(mem, "output_size_in_bytes", 0) or 0),
    }

    # 2) cost extrapolation from unrolled 1- and 2-superblock variants
    pattern = len(cfg.superblock())
    costs, colls = [], []
    for nsb in (1, 2):
        cfg_n = dataclasses.replace(cfg, num_layers=pattern * nsb,
                                    scan_layers=False,
                                    enc_layers=min(cfg.enc_layers, nsb)
                                    if cfg.enc_layers else 0)
        comp_n, low_n, _ = lower_cell(cfg_n, kind, seq, batch, mesh,
                                      num_groups)
        costs.append(_cost_dict(comp_n))
        cb = roof.collective_bytes(comp_n.as_text())
        colls.append(cb)
    nsb_full = cfg.num_superblocks
    cost_full = roof.extrapolate(costs[0], costs[1], nsb_full)
    coll_full = roof.extrapolate(
        {k: v for k, v in colls[0].items() if k != "_counts"},
        {k: v for k, v in colls[1].items() if k != "_counts"}, nsb_full)
    # encoder stack (seamless) scales with its own depth; the nsb=1/2 pair
    # uses enc_layers=1/2 so the same linear extrapolation covers it.

    total_coll = float(sum(coll_full.values()))
    mesh_shape = dict(mesh.shape)
    traffic_model = traffic.analytic_bytes(cfg, kind, seq, batch, mesh_shape)
    rl = roof.Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=cost_full["flops"], hlo_bytes=traffic_model["total"],
        coll_bytes=total_coll, coll_by_op=coll_full,
        model_flops=roof.model_flops_for(cfg, kind, seq, batch),
        per_device_mem=mem_info["resident_bytes"])

    art = {"status": "ok", "kind": kind, "seq": seq, "global_batch": batch,
           "compile_seconds": t_compile,
           "total_seconds": time.time() - t_all,
           "memory": mem_info,
           "hlo_bytes_raw": cost_full["bytes"],  # CPU-backend upper bound
           "traffic_breakdown": traffic_model,
           "collective_counts_nsb2": colls[1].get("_counts"),
           **rl.to_dict()}
    if save:
        with open(_art_path(arch, shape, mesh_name, tag), "w") as f:
            json.dump(art, f, indent=1)
    return art


# ---------------- CLI ----------------

def _run_all(multi_pod: bool, skip_existing: bool, tag: str = ""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    results = []
    for arch in configs.list_archs():
        for shape in configs.SHAPE_NAMES:
            path = _art_path(arch, shape, mesh_name, tag)
            if skip_existing and os.path.exists(path):
                print(f"[skip existing] {arch} {shape}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if tag:
                cmd += ["--tag", tag]
            if multi_pod:
                cmd.append("--multi-pod")
            print(f"[run] {arch} {shape} {mesh_name}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600,
                               env={**os.environ,
                                    "PYTHONPATH": os.environ.get(
                                        "PYTHONPATH", "src")})
            if r.returncode != 0:
                print(r.stdout[-2000:])
                print(r.stderr[-4000:])
                results.append((arch, shape, "FAIL"))
            else:
                results.append((arch, shape, "ok"))
    print("\n=== dry-run summary ===")
    for a, s, st in results:
        print(f"{a:26s} {s:12s} {st}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=configs.SHAPE_NAMES)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    if args.all:
        _run_all(args.multi_pod, args.skip_existing, args.tag)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    art = run_cell(args.arch, args.shape, args.multi_pod, tag=args.tag)
    if art["status"] == "skipped":
        print(f"SKIPPED: {art['reason']}")
        return
    print(json.dumps({k: v for k, v in art.items()
                      if k not in ("coll_by_op",)}, indent=1, default=str))
    print(f"resident per device: "
          f"{art['memory']['resident_bytes']/2**30:.2f} GiB")
    print(f"t_compute={art['t_compute']:.4e}s t_memory={art['t_memory']:.4e}s"
          f" t_collective={art['t_collective']:.4e}s ->"
          f" bottleneck={art['bottleneck']}")


if __name__ == "__main__":
    main()
