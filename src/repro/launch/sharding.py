"""Sharding rules: ModelConfig + mesh -> PartitionSpec pytrees.

Scheme (MaxText-style 2D/3D):
  * ``model`` axis = tensor parallelism (attention heads / FFN hidden / vocab)
  * ``data``  axis = batch parallelism + FSDP weight sharding (each weight's
    non-TP dim is sharded over ``data``; GSPMD all-gathers at use — ZeRO-3)
  * ``pod``   axis (multi-pod) = pure data parallelism: the only cross-pod
    traffic is the gradient all-reduce, which is what a 2-pod mesh must prove.

Every rule is divisibility-checked: a dim is sharded over an axis only when
evenly divisible (GQA KV heads (4/8) and 24-head configs replicate over
``model`` instead of failing; their FSDP dim still shards).

Decode KV caches shard the *sequence* dim over ``model`` (verified to lower
DUS without collectives), which is what makes 32k/512k-token caches fit.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

FSDP_AXIS = "data"
TP_AXIS = "model"


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return int(mesh.shape.get(name, 1))


def _div(dim: int, mesh: Mesh, axis) -> Any:
    """axis if it evenly divides dim else None (replicate)."""
    return axis if dim % max(axis_size(mesh, axis), 1) == 0 else None


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh, serve_mode: bool = False) -> P:
    """Sharding rule for one parameter leaf, dispatched on its key path.

    Stacked block params carry a leading [NSB] axis — rules index from the
    right so they apply to both stacked and unstacked layouts.

    ``serve_mode``: inference layout — TP-only, replicated over ``data`` (no
    optimizer state, bf16 params): decode reads every weight once per token,
    so per-token FSDP all-gathers would dominate (measured, §Perf).
    """
    name = path[-1]
    fs, tp = (None, TP_AXIS) if serve_mode else (FSDP_AXIS, TP_AXIS)

    def spec(*dims_from_right):
        """Build a full-rank spec given specs for the trailing dims."""
        lead = (None,) * (len(shape) - len(dims_from_right))
        return P(*(lead + dims_from_right))

    if name in ("tok", "unembed"):                       # [V, D]
        return spec(_div(shape[-2], mesh, tp), _div(shape[-1], mesh, fs))
    if name == "wq":                                     # [.., D, H, dh]
        return spec(_div(shape[-3], mesh, fs), _div(shape[-2], mesh, tp),
                    None)
    if name in ("wk", "wv"):                             # [.., D, KV, dh]
        return spec(_div(shape[-3], mesh, fs), _div(shape[-2], mesh, tp),
                    None)
    if name == "wo":                                     # [.., H, dh, D]
        return spec(_div(shape[-3], mesh, tp), None, _div(shape[-1], mesh,
                                                          fs))
    if name in ("gate", "up", "down"):
        # dense [.., D, F] / [.., F, D]  or  moe stacks [.., E, D, F]
        d1 = _div(shape[-2], mesh, tp if name == "down" else fs)
        d2 = _div(shape[-1], mesh, fs if name == "down" else tp)
        if serve_mode and len(shape) >= 3 and shape[-3] > 1:
            # serve-mode expert stacks can't replicate over `data` (mixtral:
            # 126B expert params): expert-parallel over `data` when E
            # divides, else keep FSDP on the non-TP dim (per-token gather,
            # documented tradeoff).
            e_ax = _div(shape[-3], mesh, FSDP_AXIS)
            if e_ax is None:
                d1 = _div(shape[-2], mesh,
                          tp if name == "down" else FSDP_AXIS)
                d2 = _div(shape[-1], mesh,
                          FSDP_AXIS if name == "down" else tp)
            lead = (None,) * (len(shape) - 3)
            return P(*(lead + (e_ax, d1, d2)))
        return spec(d1, d2)
    if name == "router":                                 # [.., D, E]
        return spec(_div(shape[-2], mesh, fs), None)
    if name == "in_proj":                                # [.., D, 2di+2ds+nh]
        return spec(_div(shape[-2], mesh, fs), _div(shape[-1], mesh, tp))
    if name == "out_proj":                               # [.., di, D]
        return spec(_div(shape[-2], mesh, tp), _div(shape[-1], mesh, fs))
    if name == "conv_w":                                 # [.., K, C]
        return spec(None, _div(shape[-1], mesh, tp))
    # norms, biases, per-head scalars: replicate
    return P(*((None,) * len(shape)))


def _path_names(key_path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in key_path)


def param_specs(params_shape: Any, mesh: Mesh, cfg: ModelConfig,
                serve_mode: bool = False) -> Any:
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [param_spec(_path_names(kp), tuple(leaf.shape), mesh, serve_mode)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shape: Dict, mesh: Mesh) -> Dict:
    """Leading-axis batch sharding over (pod, data); scalars replicated."""
    ba = batch_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        if v.ndim == 0 or v.shape[0] % max(axis_size(mesh, ba), 1) != 0:
            out[k] = P()
        else:
            out[k] = P(ba)
    return out


def cache_specs(caches_shape: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    """KV caches: [NSB, B, S, KV, dh] -> batch over (pod,data) if divisible,
    S over model. Mamba states: heads over model. Cross memory: tokens over
    model."""
    ba = batch_axes(mesh)
    nb = axis_size(mesh, ba)

    def leaf_spec(key_path, leaf):
        path = _path_names(key_path)
        name = path[-1]
        shape = tuple(leaf.shape)
        b_ax = ba if shape[1] % nb == 0 else None  # dim 1 = batch (0 = NSB)
        if name in ("k", "v", "mk", "mv"):         # [NSB, B, S, KV, dh]
            return P(None, b_ax, _div(shape[2], mesh, TP_AXIS), None, None)
        if name == "h":                            # [NSB, B, nh, hd, ds]
            return P(None, b_ax, _div(shape[2], mesh, TP_AXIS), None, None)
        if name in ("cx", "cb", "cc"):             # [NSB, B, K-1, C]
            return P(None, b_ax, None, _div(shape[3], mesh, TP_AXIS))
        return P(*((None,) * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(kp, lf) for kp, lf in flat])


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def logits_spec(cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    ba = batch_axes(mesh)
    b_ax = ba if batch % max(axis_size(mesh, ba), 1) == 0 else None
    v_ax = TP_AXIS if cfg.vocab % axis_size(mesh, TP_AXIS) == 0 else None
    return P(b_ax, v_ax)
