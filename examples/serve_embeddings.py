"""Serve node embeddings online: graph -> walks -> SGNS -> EmbeddingService.

The serving-side companion of quickstart.py (see also serve_decode.py for
the LM serving path). Trains a small node2vec model, makes it resident in
an EmbeddingService, then answers the two production query shapes — "embed
this node" and "rank this node's neighbors" — first directly, then through
the deadline-aware request queue under a burst of Zipf traffic.

    PYTHONPATH=src python examples/serve_embeddings.py
"""
import numpy as np

from repro.core.node2vec import Node2VecConfig
from repro.data import open_graph
from repro.engine import WalkPlan
from repro.serve import EmbeddingService, synthetic_trace

# relabel=degree makes vertex id == degree rank: the cache admission
# policy's hot prefix and Zipf query popularity line up by construction
store = open_graph("wec:k=9,deg=20,seed=0,relabel=degree")     # 512 vertices
graph = store.graph
print(f"graph: {graph.n} vertices, {graph.m} edges, "
      f"max degree {graph.max_degree}")

cfg = Node2VecConfig(walk_length=30, num_walks=3, dim=64, epochs=1,
                     batch_size=4096, cap=32, seed=0)
service = EmbeddingService.from_node2vec(
    store, cfg, plan=WalkPlan(backend="reference", cap=32),
    cache_size=128, linger_s=2e-4, margin_s=1e-3)
print(f"service resident: emb {service.emb.shape}, "
      f"buckets {service.batcher.buckets}")

# --- direct queries ------------------------------------------------------
hub = 0                                 # degree rank 0 == biggest hub
e = service.embed([hub], window=0)[0]
e_ctx = service.embed([hub], window=5)[0]       # walk-averaged context
print(f"embed({hub}): plain vs walk-averaged cosine "
      f"{float(e @ e_ctx):.3f}")

ids, scores = service.rank_neighbors([hub], k=5)
print(f"rank_neighbors({hub}, k=5): {ids[0].tolist()} "
      f"scores {np.round(scores[0], 3).tolist()}")

# --- queued serving under Zipf traffic -----------------------------------
for b in service.batcher.buckets:       # warm the jit buckets once
    service.embed([0] * b)
    service.rank_neighbors([0] * b, k=5)
for ev in synthetic_trace(graph.n, 1000, alpha=1.2, qps=20_000.0, seed=0):
    service.submit(ev.kind, ev.node, k=5, deadline_s=ev.deadline_s)
    service.pump()
service.drain()

st = service.stats()
print(f"served {st.requests} requests in {st.batches} batches: "
      f"p50 {st.p50_latency_us:.0f}us p99 {st.p99_latency_us:.0f}us "
      f"QPS {st.qps:.0f} hit-rate {st.cache_hit_rate:.2f} "
      f"occupancy {st.batch_occupancy:.2f}")
