"""Distributed Fast-Node2Vec across 8 (simulated) devices, with a mid-run
"node failure" and an elastic resume on a DIFFERENT device count — the
FN-Multi fault-tolerance story end to end, all through the unified
WalkEngine API (the runner builds a ``backend="sharded"`` engine once and
reuses its compiled walk across rounds).

    PYTHONPATH=src python examples/distributed_walks.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.core.node2vec import Node2VecConfig  # noqa: E402
from repro.data import open_graph  # noqa: E402
from repro.engine import WalkEngine  # noqa: E402
from repro.runtime.balance import shard_balance  # noqa: E402
from repro.runtime.fault_tolerance import WalkRoundRunner  # noqa: E402

# degree-descending relabel: hubs become the contiguous id prefix, so the
# range partition below spreads FN-Cache hot rows evenly across shards
graph = open_graph("skew:s=3,k=10,deg=25,seed=0,relabel=degree").graph
print(f"graph: {graph.n} vertices, {graph.m} edges, "
      f"max degree {graph.max_degree}")
rep = shard_balance(graph, num_shards=8, cap=32)
print(f"shard balance: raw edge imbalance {rep.edge_imbalance:.2f}x, "
      f"post-cap work imbalance {rep.capped_imbalance:.2f}x")

cfg = Node2VecConfig(p=0.5, q=2.0, walk_length=20, num_walks=3, cap=32,
                     seed=7)
mesh = Mesh(np.array(jax.devices()), ("rw",))

# one-off engine run: the structured stats the old call path discarded
eng = WalkEngine.build(graph, cfg.plan(mesh), mesh=mesh)
res = eng.run(seed=7)
print(f"engine stats: dropped={res.stats.dropped} "
      f"supersteps={res.stats.supersteps} "
      f"collective~{res.stats.collective_bytes / 2**20:.1f} MiB/dev "
      f"(analytic NEIG estimate)")

ckpt_dir = "/tmp/repro_example_walks"
ck = Checkpointer(ckpt_dir)

runner = WalkRoundRunner(graph, cfg, mesh=mesh, checkpointer=ck)
it = runner.rounds()
print("round 0:", next(it).shape)
print("round 1:", next(it).shape)
del it, runner          # simulate a crash after 2 of 3 rounds
ck.wait()

# elastic resume on FEWER devices (first 4): same walks, bit-identical
mesh_small = Mesh(np.array(jax.devices()[:4]), ("rw",))
resumed = WalkRoundRunner(graph, cfg, mesh=mesh_small,
                          checkpointer=Checkpointer(ckpt_dir))
rounds = list(resumed.rounds())
print(f"resumed on 4 devices: {len(rounds)} rounds, "
      f"{rounds[-1].shape[0]} walks each")
print("fault-tolerant, elastic, deterministic: OK")
