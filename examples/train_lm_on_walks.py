"""Train an assigned LM architecture on a graph-walk corpus (DeepWalk-style):
the walk engine is the framework's graph-data pipeline; any of the 10 archs
consumes it. Uses the reduced (smoke) config so it runs on CPU.

    PYTHONPATH=src python examples/train_lm_on_walks.py --arch mamba2-370m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import rmat
from repro.data.corpus import walks_to_lm_tokens
from repro.engine import WalkEngine, WalkPlan
from repro.models import model as M
from repro.optim.grad_utils import clip_by_global_norm
from repro.optim.optimizers import adamw, apply_updates

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mamba2-370m")
ap.add_argument("--steps", type=int, default=15)
args = ap.parse_args()

cfg = configs.smoke_config(args.arch)
graph = rmat.wec(9, avg_degree=15, seed=0)
walks = WalkEngine.build(
    graph, WalkPlan(p=1.0, q=0.5, length=64)).run(seed=0).walks
tokens = walks_to_lm_tokens(walks % cfg.vocab, seq_len=33)
print(f"arch={args.arch} corpus={tokens.shape}")

params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw(3e-3)
opt_state = opt.init(params)


@jax.jit
def step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch))(params)
    grads, _ = clip_by_global_norm(grads, 1.0)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


rng = np.random.default_rng(0)
extras = {}
if cfg.enc_layers:
    extras["frames"] = jnp.zeros((8, cfg.num_audio_frames, cfg.d_model),
                                 jnp.float32)
if cfg.cross_every and not cfg.enc_layers:
    extras["patches"] = jnp.zeros((8, cfg.num_image_tokens, cfg.d_model),
                                  jnp.float32)
t0 = time.time()
for i in range(args.steps):
    seqs = tokens[rng.integers(0, tokens.shape[0], 8)]
    batch = {"tokens": jnp.asarray(seqs[:, :-1]),
             "labels": jnp.asarray(seqs[:, 1:]), **extras}
    params, opt_state, loss = step(params, opt_state, batch)
    if i % 5 == 0 or i == args.steps - 1:
        print(f"step {i:3d}  loss {float(loss):.4f}  "
              f"({time.time() - t0:.1f}s)")
print("done")
