"""Node classification (the paper's Fig. 6 experiment, end to end).

Trains Node2Vec embeddings on a labeled community graph three ways — exact,
FN-Approx, and the Spark trim baseline — then fits a linear probe and prints
micro-F1 for each, reproducing the paper's quality ranking:
exact ≈ approx >> spark-trim.

    PYTHONPATH=src python examples/classify_nodes.py
"""
import numpy as np

from repro.core.node2vec import (Node2VecConfig, generate_walks,
                                 train_embeddings)
from repro.data import open_graph

store = open_graph("sbm:n=400,c=4,pin=0.06,pout=0.004,seed=1")
graph, labels = store.graph, store.labels
rng = np.random.default_rng(0)
graph.wgt = (rng.random(graph.m) * 4 + 0.5).astype(np.float32)
print(f"graph: {graph.n} vertices, {graph.m} edges, 4 communities")


def probe_accuracy(emb):
    idx = np.random.default_rng(0).permutation(graph.n)
    tr, te = idx[:graph.n // 2], idx[graph.n // 2:]
    y = np.eye(4)[labels]
    w, *_ = np.linalg.lstsq(emb[tr], y[tr], rcond=None)
    return ((emb[te] @ w).argmax(1) == labels[te]).mean()


base = dict(p=1.0, q=0.5, walk_length=20, num_walks=4, window=5, dim=32,
            epochs=2, batch_size=4096, seed=0)

for name, g, cfg in [
    ("fn_exact", graph, Node2VecConfig(mode="exact", **base)),
    ("fn_approx", graph, Node2VecConfig(mode="approx", approx_eps=5e-2,
                                        cap=16, **base)),
    ("spark_trim", graph.trim_top_weights(4),
     Node2VecConfig(mode="exact", **base)),
]:
    walks = generate_walks(g, cfg)
    emb = train_embeddings(g, walks, cfg)
    print(f"{name:12s} micro-F1 = {probe_accuracy(emb):.3f}")
