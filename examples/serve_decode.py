"""Batched serving example: prefill a batch of prompts, then stream decode —
shows the sub-quadratic decode paths (mamba2 state / jamba hybrid / mixtral
SWA ring buffer) that make long_500k serveable.

    PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="jamba-v0.1-52b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt", type=int, default=24)
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

cfg = configs.smoke_config(args.arch)
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
b, s = args.batch, args.prompt
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                               jnp.int32)}
if cfg.enc_layers:
    batch["frames"] = jnp.zeros((b, cfg.num_audio_frames, cfg.d_model),
                                jnp.float32)
if cfg.cross_every and not cfg.enc_layers:
    batch["patches"] = jnp.zeros((b, cfg.num_image_tokens, cfg.d_model),
                                 jnp.float32)

prefill = jax.jit(lambda p, bb: M.prefill(cfg, p, bb,
                                          max_len=s + args.gen))
decode = jax.jit(lambda p, t, pos, c: M.serve_step(cfg, p, t, pos, c))

logits, caches = prefill(params, batch)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [np.asarray(tok)]
t0 = time.time()
for i in range(args.gen - 1):
    logits, caches = decode(params, tok, jnp.asarray(s + i, jnp.int32),
                            caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(np.asarray(tok))
jax.block_until_ready(tok)
ms = (time.time() - t0) / max(args.gen - 1, 1) * 1e3
print(f"arch={args.arch} family={cfg.family} "
      f"subquadratic={cfg.subquadratic}")
print(f"decoded {args.gen} tokens x {b} seqs, {ms:.1f} ms/token (CPU)")
print("first sequence:", np.stack(out, 1)[0].tolist())
