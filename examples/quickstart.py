"""Quickstart: Fast-Node2Vec end to end in ~30 lines, through the unified
WalkEngine API.

Loads a small social-like graph from the dataset registry (swap the spec
for ``"edgelist:/path/to/edges.txt"`` to walk a real on-disk graph),
declares a WalkPlan (FN-Cache layout, exact 2nd-order sampling), streams
FN-Multi walk rounds from the engine, trains SGNS embeddings, and prints
nearest neighbors of the highest-degree vertex in embedding space. Swap
``backend="reference"`` for ``"fused"`` (Pallas step kernel) or
``"sharded"`` (multi-device) — same walks, same seed.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.node2vec import Node2VecConfig, train_embeddings
from repro.data import open_graph
from repro.engine import WalkEngine, WalkPlan

store = open_graph("wec:k=10,deg=30,seed=0")         # 1024 vertices
graph = store.graph
print(f"graph: {graph.n} vertices, {graph.m} edges, "
      f"max degree {graph.max_degree}")

plan = WalkPlan(
    p=1.0, q=0.5,            # DFS-ish exploration (community features)
    length=40,
    cap=32,                  # FN-Cache layout: popular rows replicated
    backend="reference")
engine = WalkEngine.build(graph, plan)

rounds = list(engine.rounds(4, seed=0))              # FN-Multi: 4 rounds
stats = rounds[0].stats
print(f"round stats: backend={stats.backend} walkers={stats.walkers} "
      f"supersteps={stats.supersteps} dropped={stats.dropped}")
walks = np.concatenate([r.walks for r in rounds], axis=0)

cfg = Node2VecConfig(window=5, dim=64, epochs=2, batch_size=4096, seed=0)
emb = train_embeddings(graph, walks, cfg)
print(f"embeddings: {emb.shape}")

v = int(np.argmax(graph.deg))
sims = emb @ emb[v]
top = np.argsort(-sims)[1:6]
print(f"most similar to hub vertex {v}: {top.tolist()}")
print("overlap with actual neighbors:",
      len(set(top.tolist()) & set(graph.neighbors(v).tolist())), "/ 5")
