"""Quickstart: Fast-Node2Vec end to end in ~30 lines.

Builds a small social-like RMAT graph, runs exact 2nd-order walks with the
FN-Cache layout, trains SGNS embeddings, and prints nearest neighbors of the
highest-degree vertex in embedding space.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import rmat
from repro.core.node2vec import Node2VecConfig, node2vec

graph = rmat.wec(10, avg_degree=30, seed=0)          # 1024 vertices
print(f"graph: {graph.n} vertices, {graph.m} edges, "
      f"max degree {graph.max_degree}")

cfg = Node2VecConfig(
    p=1.0, q=0.5,            # DFS-ish exploration (community features)
    walk_length=40, num_walks=4, window=5,
    dim=64, epochs=2, batch_size=4096,
    cap=32,                  # FN-Cache layout: popular rows replicated
    seed=0)

emb = node2vec(graph, cfg)
print(f"embeddings: {emb.shape}")

v = int(np.argmax(graph.deg))
sims = emb @ emb[v]
top = np.argsort(-sims)[1:6]
print(f"most similar to hub vertex {v}: {top.tolist()}")
print("overlap with actual neighbors:",
      len(set(top.tolist()) & set(graph.neighbors(v).tolist())), "/ 5")
